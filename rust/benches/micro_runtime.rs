//! Runtime microbenchmarks (wall-clock, criterion-style): the §Perf
//! numbers for the L3 hot paths, plus the host-backend scaling smoke.
//!
//!   - Chase-Lev deque push/pop/steal
//!   - simulator dispatch rate (coroutine steps/s)
//!   - cache-model access cost
//!   - host executor job dispatch overhead
//!   - Algorithm 2 placement-map computation
//!   - host-backend *scaling* over a workers axis (`--workers 1,8`):
//!     a fixed memory-bound GUPS workload split across N real workers.
//!     With sharded machine accounting, multi-worker wall time must beat
//!     single-worker (steps charge disjoint shards concurrently); CI
//!     pins this with `--assert-scaling` and the run emits
//!     `BENCH_host_scaling.json` for trend tracking.
//!   - *scheduler overhead*: steps/sec at zero work per backend × batch
//!     budget (`--batch-steps` semantics), emitting
//!     `BENCH_sched_overhead.json`. This is the run-until-yield batching
//!     claim as a number: the batched host pipeline must beat
//!     `--batch-steps 1` (pool round-trip per step) by ≥ 2× on ≥ 4
//!     workers; CI pins it with `--assert-overhead` + the bench-check
//!     gate.
//!   - *adaptive migration payoff*: the phase-shift scenario (message-
//!     bound phase A, bandwidth-bound phase B — no static placement is
//!     right for both) on the **host backend** with the real-time
//!     controller tick armed. The adaptive policy must migrate at the
//!     shift and beat the best static policy's modeled makespan;
//!     emits `BENCH_adaptive.json` with
//!     `speedup_adaptive_vs_best_static`, gated by `--assert-adaptive`
//!     + the bench-check `--kind adaptive` gate.
//!   - *memory-follows-tasks payoff*: the mem-follow scenario (the group
//!     compacts onto NUMA 0 while its stream region stays `Bind`-stranded
//!     on the last NUMA node) on the deterministic **sim backend** with
//!     the virtual-time tick armed, run twice: task-move-only
//!     (`with_region_moves(false)`) vs full adaptation. Region moves
//!     must fire and strictly beat the task-move-only makespan; emits
//!     `BENCH_mem_follow.json` with `speedup_moves_vs_task_only`, gated
//!     by `--assert-mem-follow` + the bench-check `--kind mem-follow`
//!     gate.
//!
//! Flags: `--workers a,b,..` sets the scaling axis, `--scaling-only` /
//! `--overhead-only` / `--adaptive-only` / `--mem-follow-only` select
//! one section (CI), `--assert-scaling` / `--assert-overhead` /
//! `--assert-adaptive` / `--assert-mem-follow` make the respective
//! bound fatal.

use arcas::controller::placement_map;
use arcas::deque::Deque;
use arcas::engine::{ExecBackend, Run, DEFAULT_BATCH_STEPS};
use arcas::mem::Placement;
use arcas::policy::{by_name, ArcasPolicy, LocalCachePolicy, ShoalPolicy};
use arcas::sched::HostExecutor;
use arcas::sim::Machine;
use arcas::task::IterTask;
use arcas::topology::Topology;
use arcas::util::bench::Bencher;
use arcas::util::cli::{Args, Cli};
use arcas::workloads::graph::GupsScenario;
use arcas::workloads::phaseshift::{MemFollowScenario, PhaseShiftScenario};

fn cli() -> Cli {
    Cli::new("micro_runtime", "runtime microbenchmarks + host scaling smoke")
        .opt(
            "workers",
            "1,8",
            "host-backend scaling axis: comma-separated worker counts",
        )
        .opt("scaling-reps", "3", "repetitions per workers point (best-of)")
        .flag("assert-scaling", "fail unless max-workers beats 1-worker wall time")
        .flag("scaling-only", "run only the host-backend scaling section")
        .flag(
            "assert-overhead",
            "fail unless batched host steps/sec beats --batch-steps 1 by 2x",
        )
        .flag("overhead-only", "run only the scheduler-overhead section")
        .flag(
            "assert-adaptive",
            "fail unless adaptive migrates and beats the best static makespan",
        )
        .flag("adaptive-only", "run only the adaptive-migration section")
        .flag(
            "assert-mem-follow",
            "fail unless region moves fire and beat the task-move-only makespan",
        )
        .flag("mem-follow-only", "run only the memory-follows-tasks section")
        .flag("quick", "smaller runs for smoke testing")
        .flag("bench", "(passed by `cargo bench`; ignored)")
}

/// Scaling topology: Milan with **one core per CCD**, so worker *i* =
/// core *i* = chiplet-shard *i*. Every worker owns a whole
/// `ChipletShard`; what stays shared is exactly what hardware shares —
/// the DDR trackers, coherence invalidations and remote residency
/// probes. A regression that re-serializes shard accounting (a global
/// machine lock) shows up directly on this axis instead of hiding
/// behind the workload's own unlocked compute.
fn scaling_topo() -> Topology {
    let mut t = Topology::milan_1s();
    t.cores_per_chiplet = 1;
    t.name = "milan_1s_1cpc".into();
    t
}

/// One host-backend run: `workers` ranks (Shoal places rank i on core i,
/// so the pool is exactly `workers` threads, each on its own chiplet
/// shard under [`scaling_topo`]) splitting a fixed total of GUPS updates
/// over a 16 MiB table — memory-bound in the model *and* genuinely
/// parallel real work (atomic XORs over the table). Returns wall ns.
fn host_scaling_run(topo: &Topology, workers: usize, total_updates: u64, seed: u64) -> u64 {
    let per_rank = (total_updates / workers as u64).max(1);
    let mut s = GupsScenario::new(1 << 21, per_rank, seed);
    let run = Run::new(topo)
        .policy(Box::new(ShoalPolicy::new()))
        .tasks(workers)
        .backend(ExecBackend::Host)
        .run(&mut s);
    run.report.wall_ns
}

/// The host-backend scaling smoke. Returns false when `--assert-scaling`
/// is set and the bound is violated.
fn host_scaling(args: &Args) -> bool {
    let topo = scaling_topo();
    let axis: Vec<usize> = args
        .u64_list("workers")
        .iter()
        .map(|&w| (w as usize).clamp(1, topo.num_cores()))
        .collect();
    assert!(!axis.is_empty(), "--workers needs at least one point");
    let total_updates: u64 = if args.flag("quick") { 400_000 } else { 2_000_000 };
    let reps = args.u64("scaling-reps").max(1);

    println!("### host-backend scaling (sharded machine accounting)");
    println!(
        "# scenario=gups table=16MiB total_updates={total_updates} backend=host reps={reps} \
         (best-of); topology={} (1 core/CCD: worker i = shard i)",
        topo.name
    );
    let mut points: Vec<(usize, u64)> = Vec::new();
    for &w in &axis {
        let mut best = u64::MAX;
        for rep in 0..reps {
            best = best.min(host_scaling_run(&topo, w, total_updates, 42 + rep));
        }
        println!(
            "  workers={w:<3} wall = {:>10.3} ms  ({:.1} M updates/s real)",
            best as f64 / 1e6,
            total_updates as f64 / best as f64 * 1e3
        );
        points.push((w, best));
    }

    // Emit BENCH_host_scaling.json for CI artifacts / trend tracking.
    let wall_1 = points.iter().find(|(w, _)| *w == 1).map(|&(_, ns)| ns);
    let (w_max, wall_max) = *points.iter().max_by_key(|(w, _)| *w).unwrap();
    let speedup = wall_1.map(|w1| w1 as f64 / wall_max as f64);
    let json_points: Vec<String> = points
        .iter()
        .map(|(w, ns)| format!("{{\"workers\": {w}, \"wall_ns\": {ns}}}"))
        .collect();
    // "pinned": true + "tol" so copying this file over ci/baselines/
    // (the bench-check re-pin flow) yields a live gate with the intended
    // band (loose: shared-runner speedups are a smoke signal).
    let json = format!(
        "{{\n  \"bench\": \"host_scaling\",\n  \"scenario\": \"gups\",\n  \
         \"backend\": \"host\",\n  \"pinned\": true,\n  \"tol\": 0.35,\n  \
         \"total_updates\": {total_updates},\n  \
         \"points\": [{}],\n  \"speedup_max_vs_1\": {}\n}}\n",
        json_points.join(", "),
        speedup.map_or("null".to_string(), |s| format!("{s:.3}")),
    );
    let path = std::path::Path::new("BENCH_host_scaling.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!(
            "  => wrote {}",
            std::fs::canonicalize(path)
                .unwrap_or_else(|_| path.to_path_buf())
                .display()
        ),
        Err(e) => println!("  => could not write BENCH_host_scaling.json: {e}"),
    }

    // The smoke assertion: more workers must actually help. The bound is
    // deliberately loose (CI runners have few cores and 8 oversubscribed
    // threads still beat 1), but a serialized machine — the pre-shard
    // global mutex — fails it decisively (speedup there was ~1.0x).
    if let (Some(w1), true) = (wall_1, w_max > 1) {
        let speedup = w1 as f64 / wall_max as f64;
        let ok = wall_max as f64 <= w1 as f64 * 0.9;
        println!(
            "  => speedup {w_max}-worker vs 1-worker: {speedup:.2}x ({})",
            if ok { "pass" } else { "FAIL: expected > 1.11x" }
        );
        if args.flag("assert-scaling") && !ok {
            return false;
        }
    } else if args.flag("assert-scaling") {
        println!("  => --assert-scaling needs a workers axis spanning 1 and >1");
        return false;
    }
    true
}

/// The scheduler-overhead microbench: steps/sec at **zero work** per
/// backend × batch budget. With no workload cost, wall time is pure
/// runtime overhead — submit/park/wake round-trips, queue traffic,
/// probe-cache setup — so the batch axis isolates exactly what
/// run-until-yield batching amortizes. 8 ranks spread over 8 one-core
/// chiplet shards by Shoal (worker *i* = shard *i*), well past the
/// ≥ 4-worker bar the 2× acceptance bound is defined on. Returns false
/// when `--assert-overhead` is set and batched host throughput fails to
/// double the `--batch-steps 1` pipeline.
fn sched_overhead(args: &Args) -> bool {
    let topo = scaling_topo();
    let ranks = 8usize;
    let (steps_per_rank, reps) = if args.flag("quick") {
        (2_000usize, 2u64)
    } else {
        (10_000usize, 3u64)
    };
    let total_steps = (ranks * steps_per_rank) as u64;
    println!("### scheduler overhead (steps/sec at zero work)");
    println!(
        "# ranks={ranks} steps/rank={steps_per_rank} reps={reps} (best-of); \
         topology={} (1 core/CCD: worker i = shard i)",
        topo.name
    );

    // Best-of-reps wall time for one backend × batch point (batch is
    // host-only; the deterministic sim ignores it).
    let run_point = |backend: ExecBackend, batch: usize| -> f64 {
        let mut best = u64::MAX;
        for _ in 0..reps {
            let (r, _) = Run::new(&topo)
                .policy(Box::new(ShoalPolicy::new()))
                .tasks(ranks)
                .backend(backend)
                .batch_steps(batch)
                .run_group(|_| Box::new(IterTask::new(steps_per_rank, |_, _| {})));
            assert_eq!(r.dispatches, total_steps, "batching must not change step counts");
            best = best.min(r.wall_ns.max(1));
        }
        total_steps as f64 / (best as f64 / 1e9)
    };

    // points: (backend, batch_steps, steps_per_sec, tol). batch_steps 0
    // marks the sim reference (no pool, budget not applicable).
    let host_batches = [1usize, DEFAULT_BATCH_STEPS, 64];
    let mut points: Vec<(&str, usize, f64, f64)> = Vec::new();
    for &batch in &host_batches {
        let sps = run_point(ExecBackend::Host, batch);
        println!("  host  batch={batch:<4} {:>10.2} M steps/s", sps / 1e6);
        points.push(("host", batch, sps, 0.50));
    }
    let sim_sps = run_point(ExecBackend::Sim, DEFAULT_BATCH_STEPS);
    println!("  sim   (n/a)      {:>10.2} M steps/s", sim_sps / 1e6);
    points.push(("sim", 0, sim_sps, 0.50));

    let sps_of = |batch: usize| points.iter().find(|p| p.0 == "host" && p.1 == batch).unwrap().2;
    let speedup = sps_of(DEFAULT_BATCH_STEPS) / sps_of(1);
    println!(
        "  => batched (batch={DEFAULT_BATCH_STEPS}) vs per-step: {speedup:.2}x ({})",
        if speedup >= 2.0 { "pass" } else { "FAIL: expected >= 2x" }
    );

    // Emit BENCH_sched_overhead.json ("pinned": true + per-point tol so
    // the bench-check re-pin flow yields a live gate; host points are
    // loose for shared-runner noise).
    let json_points: Vec<String> = points
        .iter()
        .map(|(backend, batch, sps, tol)| {
            format!(
                "{{\"backend\": \"{backend}\", \"batch_steps\": {batch}, \
                 \"steps_per_sec\": {sps:.1}, \"tol\": {tol}}}"
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"sched_overhead\",\n  \"pinned\": true,\n  \"tol\": 0.40,\n  \
         \"config\": {{\"ranks\": {ranks}, \"steps_per_rank\": {steps_per_rank}, \
         \"quick\": {}}},\n  \
         \"points\": [{}],\n  \"speedup_batched_vs_1\": {speedup:.3}\n}}\n",
        args.flag("quick"),
        json_points.join(",\n             "),
    );
    let path = std::path::Path::new("BENCH_sched_overhead.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!(
            "  => wrote {}",
            std::fs::canonicalize(path)
                .unwrap_or_else(|_| path.to_path_buf())
                .display()
        ),
        Err(e) => println!("  => could not write BENCH_sched_overhead.json: {e}"),
    }

    !(args.flag("assert-overhead") && speedup < 2.0)
}

/// Adaptive-payoff topology: Milan with **four cores per CCD** (32
/// cores over 8 chiplet shards). Small enough that the adaptive pool
/// (one worker per core, so any migration target is live) stays
/// CI-friendly, while the shape keeps both phase preferences real:
/// compacting the 16-rank group onto one 4-core chiplet stacks only 4
/// ranks per core — cheaper than paying the cross-chiplet hop on every
/// phase-A ring message — and 8 chiplets of spread buy 8× L3 + DDR
/// channels for phase B's shared stream, which blows any single 32 MiB
/// L3.
fn adaptive_topo() -> Topology {
    let mut t = Topology::milan_1s();
    t.cores_per_chiplet = 4;
    t.name = "milan_1s_4cpc".into();
    t
}

/// One host-backend phase-shift run. `timer_ns: Some(t)` arms the
/// real-time adaptation tick; `None` is the static reference. Returns
/// (modeled makespan ns, migrations).
fn adaptive_run(
    topo: &Topology,
    policy: Box<dyn arcas::policy::Policy>,
    timer_ns: Option<u64>,
    steps: u64,
) -> (u64, u64) {
    let mut s = PhaseShiftScenario::new(96 << 20, steps, steps);
    let mut run = Run::new(topo)
        .policy(policy)
        .tasks(16)
        .backend(ExecBackend::Host)
        .batch_steps(4)
        .verify(true);
    if let Some(t) = timer_ns {
        run = run.timer_ns(t);
    }
    let r = run.run(&mut s);
    (r.report.makespan_ns.max(1), r.report.migrations)
}

/// The adaptive-migration payoff bench: on the phase-shift scenario no
/// static placement is right for both phases, so the adaptive policy —
/// migrating at the shift, driven by the host backend's real-elapsed
/// timer — must beat every static policy's modeled makespan. The gated
/// headline is `speedup_adaptive_vs_best_static` (higher is better);
/// migrations > 0 guards against the degenerate "adaptive won without
/// adapting" pass. Returns false when `--assert-adaptive` is set and
/// either bound fails.
fn adaptive_payoff(args: &Args) -> bool {
    let topo = adaptive_topo();
    let (steps, timer_ns, reps) = if args.flag("quick") {
        (200u64, 100_000u64, 2u64)
    } else {
        (500u64, 150_000u64, 3u64)
    };
    println!("### adaptive migration payoff (host backend, real-time tick)");
    println!(
        "# scenario=phase-shift steps/phase={steps} tasks=16 timer={}us reps={reps} \
         (best-of); topology={} (8 chiplets x 4 cores)",
        timer_ns / 1000,
        topo.name
    );

    // Static references: compact (local) and spread (distributed) — the
    // two placements the phases respectively reward, so "best static"
    // is whichever half the workload favors overall.
    let mut best_static = u64::MAX;
    let mut static_lines: Vec<String> = Vec::new();
    for name in ["local", "distributed"] {
        let mut best = u64::MAX;
        for _ in 0..reps {
            let p = by_name(name, &topo).expect("static policy");
            best = best.min(adaptive_run(&topo, p, None, steps).0);
        }
        println!("  static {name:<12} makespan = {:>10.3} ms", best as f64 / 1e6);
        static_lines.push(format!(
            "{{\"policy\": \"{name}\", \"makespan_ns\": {best}}}"
        ));
        best_static = best_static.min(best);
    }

    let mut adaptive = u64::MAX;
    let mut migrations = 0u64;
    for _ in 0..reps {
        let p = Box::new(ArcasPolicy::new(&topo));
        let (ms, mig) = adaptive_run(&topo, p, Some(timer_ns), steps);
        if ms < adaptive {
            adaptive = ms;
            migrations = mig;
        }
    }
    println!(
        "  adaptive (arcas)    makespan = {:>10.3} ms  ({migrations} migrations)",
        adaptive as f64 / 1e6
    );

    let speedup = best_static as f64 / adaptive as f64;
    let ok = migrations > 0 && speedup > 1.0;
    println!(
        "  => adaptive vs best static: {speedup:.2}x, migrations={migrations} ({})",
        if ok {
            "pass"
        } else {
            "FAIL: expected > 1.0x with migrations > 0"
        }
    );

    // Emit BENCH_adaptive.json ("pinned": true + "tol" so the bench-check
    // re-pin flow yields a live gate; the band is loose — host tick
    // timing is real elapsed time, so migration points drift run-to-run).
    let json = format!(
        "{{\n  \"bench\": \"adaptive\",\n  \"scenario\": \"phase-shift\",\n  \
         \"backend\": \"host\",\n  \"pinned\": true,\n  \"tol\": 0.35,\n  \
         \"config\": {{\"tasks\": 16, \"steps_per_phase\": {steps}, \
         \"timer_ns\": {timer_ns}, \"quick\": {}}},\n  \
         \"statics\": [{}],\n  \"adaptive_makespan_ns\": {adaptive},\n  \
         \"migrations\": {migrations},\n  \
         \"speedup_adaptive_vs_best_static\": {speedup:.3}\n}}\n",
        args.flag("quick"),
        static_lines.join(", "),
    );
    let path = std::path::Path::new("BENCH_adaptive.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!(
            "  => wrote {}",
            std::fs::canonicalize(path)
                .unwrap_or_else(|_| path.to_path_buf())
                .display()
        ),
        Err(e) => println!("  => could not write BENCH_adaptive.json: {e}"),
    }

    !(args.flag("assert-adaptive") && !ok)
}

/// One sim-backend mem-follow run: 16 ranks under the arcas policy with
/// the virtual-time tick armed, with or without region moves. Returns
/// (modeled makespan ns, region moves).
fn mem_follow_run(topo: &Topology, region_moves: bool, steps_b: u64, timer_ns: u64) -> (u64, u64) {
    let mut s = MemFollowScenario::new(2 << 30, steps_b * 2, steps_b);
    let p = Box::new(
        ArcasPolicy::new(topo)
            .with_timer(timer_ns)
            .with_region_moves(region_moves),
    );
    let r = Run::new(topo).policy(p).tasks(16).verify(true).run(&mut s);
    (r.report.makespan_ns.max(1), r.report.region_moves)
}

/// The memory-follows-tasks payoff bench: on the mem-follow scenario the
/// controller compacts the 16-rank group onto NUMA 0 during the
/// message-bound phase A, then phase B hammers a 2 GiB stream region
/// `Bind`-stranded on the *last* NUMA node. Task migration alone cannot
/// fix that — only re-homing the region can — so the full adaptive
/// policy must fire region moves and strictly beat the task-move-only
/// baseline (same policy, `with_region_moves(false)`). Runs on the sim
/// backend: virtual time makes both makespans deterministic, so the
/// headline `speedup_moves_vs_task_only` is noise-free. Returns false
/// when `--assert-mem-follow` is set and either bound fails.
fn mem_follow_payoff(args: &Args) -> bool {
    let topo = Topology::milan_1s_nps4();
    let (steps_b, timer_ns) = if args.flag("quick") {
        (60u64, 10_000u64)
    } else {
        (150u64, 10_000u64)
    };
    println!("### memory-follows-tasks payoff (sim backend, virtual-time tick)");
    println!(
        "# scenario=mem-follow region=2GiB steps_a={} steps_b={steps_b} tasks=16 \
         timer={}us; topology={} (4 NUMA x 2 chiplets x 8 cores)",
        steps_b * 2,
        timer_ns / 1000,
        topo.name
    );

    let (task_only, moves_off) = mem_follow_run(&topo, false, steps_b, timer_ns);
    assert_eq!(moves_off, 0, "with_region_moves(false) must plan no moves");
    println!(
        "  task-move-only      makespan = {:>10.3} ms  (0 region moves by construction)",
        task_only as f64 / 1e6
    );
    let (with_moves, region_moves) = mem_follow_run(&topo, true, steps_b, timer_ns);
    println!(
        "  data-follows-tasks  makespan = {:>10.3} ms  ({region_moves} region moves)",
        with_moves as f64 / 1e6
    );

    let speedup = task_only as f64 / with_moves as f64;
    let ok = region_moves > 0 && speedup > 1.0;
    println!(
        "  => region moves vs task-move-only: {speedup:.2}x, region_moves={region_moves} ({})",
        if ok {
            "pass"
        } else {
            "FAIL: expected > 1.0x with region_moves > 0"
        }
    );

    // Emit BENCH_mem_follow.json ("pinned": true + "tol" so the
    // bench-check re-pin flow yields a live gate; the sim is
    // deterministic, but the band stays loose so retuning the scenario's
    // step counts doesn't spuriously trip the gate).
    let json = format!(
        "{{\n  \"bench\": \"mem_follow\",\n  \"scenario\": \"mem-follow\",\n  \
         \"backend\": \"sim\",\n  \"pinned\": true,\n  \"tol\": 0.35,\n  \
         \"config\": {{\"tasks\": 16, \"steps_b\": {steps_b}, \
         \"timer_ns\": {timer_ns}, \"quick\": {}}},\n  \
         \"task_only_makespan_ns\": {task_only},\n  \
         \"moves_makespan_ns\": {with_moves},\n  \
         \"region_moves\": {region_moves},\n  \
         \"speedup_moves_vs_task_only\": {speedup:.3}\n}}\n",
        args.flag("quick"),
    );
    let path = std::path::Path::new("BENCH_mem_follow.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!(
            "  => wrote {}",
            std::fs::canonicalize(path)
                .unwrap_or_else(|_| path.to_path_buf())
                .display()
        ),
        Err(e) => println!("  => could not write BENCH_mem_follow.json: {e}"),
    }

    !(args.flag("assert-mem-follow") && !ok)
}

fn micro(args: &Args) {
    let mut b = if args.flag("quick") {
        Bencher::quick()
    } else {
        Bencher::new()
    };
    let topo = Topology::milan_2s();

    // --- deque ops.
    let d = Deque::new();
    b.bench("deque push+pop (owner)", || {
        d.push(1);
        d.pop()
    });
    for i in 0..1024 {
        d.push(i);
    }
    b.bench("deque steal (uncontended)", || {
        let s = d.steal();
        if let arcas::deque::Steal::Success(v) = s {
            d.push(v);
        }
        s
    });

    // --- cache model access.
    let m = Machine::new(topo.clone());
    let r = m.alloc("bench", 64 << 20, Placement::Interleave);
    b.bench("cachesim access (rand 1k ops)", || {
        m.access(0, arcas::cachesim::Access::rand_read(r, 1000, 64 << 20))
    });

    // --- simulator dispatch rate (through the engine's executor seam).
    let res = b.bench("sim dispatch (1k coroutine steps)", || {
        let machine = Machine::new(Topology::milan_1s());
        arcas::sched::run_group(machine, Box::new(LocalCachePolicy), 8, |_| {
            Box::new(IterTask::new(125, |ctx, _| ctx.compute_ns(100)))
        })
        .dispatches
    });
    println!(
        "  => {:.1} M simulated dispatches/s",
        1000.0 / res.median_ns * 1e3
    );

    // --- Algorithm 2 placement map.
    b.bench("placement_map (128 ranks)", || {
        placement_map(&topo, 4, 128)
    });

    // --- host executor dispatch overhead.
    let pool = HostExecutor::new(4, &Topology::milan_1s(), false);
    let res = b.bench("host executor 1k no-op jobs", || {
        for _ in 0..1000 {
            pool.execute(|| {});
        }
        pool.wait_all();
    });
    println!(
        "  => {:.0} ns/job dispatch overhead",
        res.median_ns / 1000.0
    );

    // --- host *backend* through the engine seam: a full group run on
    // real threads (pool spawn + 100 coroutine steps + teardown), the
    // end-to-end cost `arcas run --backend host` pays per run.
    let res = b.bench("host backend group run (100 steps)", || {
        let (r, _) = Run::new(&Topology::milan_1s())
            .policy(Box::new(LocalCachePolicy))
            .backend(ExecBackend::Host)
            .tasks(4)
            .run_group(|_| Box::new(IterTask::new(25, |ctx, _| ctx.compute_ns(100))));
        r.dispatches
    });
    println!(
        "  => {:.1} us/host-backed run (incl. pool spawn)",
        res.median_ns / 1e3
    );
}

fn main() {
    let args = cli().parse();
    let scaling_only = args.flag("scaling-only");
    let overhead_only = args.flag("overhead-only");
    let adaptive_only = args.flag("adaptive-only");
    let mem_follow_only = args.flag("mem-follow-only");
    let any_only = scaling_only || overhead_only || adaptive_only || mem_follow_only;
    if !any_only {
        micro(&args);
    }
    if (adaptive_only || !any_only) && !adaptive_payoff(&args) {
        eprintln!("adaptive-migration assertion failed");
        std::process::exit(1);
    }
    if (mem_follow_only || !any_only) && !mem_follow_payoff(&args) {
        eprintln!("memory-follows-tasks assertion failed");
        std::process::exit(1);
    }
    if (overhead_only || !any_only) && !sched_overhead(&args) {
        eprintln!("scheduler-overhead assertion failed");
        std::process::exit(1);
    }
    if (scaling_only || !any_only) && !host_scaling(&args) {
        eprintln!("host-backend scaling assertion failed");
        std::process::exit(1);
    }
}
