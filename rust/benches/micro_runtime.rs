//! Runtime microbenchmarks (wall-clock, criterion-style): the §Perf
//! numbers for the L3 hot paths.
//!
//!   - Chase-Lev deque push/pop/steal
//!   - simulator dispatch rate (coroutine steps/s)
//!   - cache-model access cost
//!   - host executor job dispatch overhead
//!   - Algorithm 2 placement-map computation

use arcas::controller::placement_map;
use arcas::deque::Deque;
use arcas::mem::Placement;
use arcas::policy::LocalCachePolicy;
use arcas::sched::HostExecutor;
use arcas::sim::Machine;
use arcas::task::IterTask;
use arcas::topology::Topology;
use arcas::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();
    let topo = Topology::milan_2s();

    // --- deque ops.
    let d = Deque::new();
    b.bench("deque push+pop (owner)", || {
        d.push(1);
        d.pop()
    });
    for i in 0..1024 {
        d.push(i);
    }
    b.bench("deque steal (uncontended)", || {
        let s = d.steal();
        if let arcas::deque::Steal::Success(v) = s {
            d.push(v);
        }
        s
    });

    // --- cache model access.
    let mut m = Machine::new(topo.clone());
    let r = m.alloc("bench", 64 << 20, Placement::Interleave);
    b.bench("cachesim access (rand 1k ops)", || {
        m.access(0, arcas::cachesim::Access::rand_read(r, 1000, 64 << 20))
    });

    // --- simulator dispatch rate (through the engine's executor seam).
    let res = b.bench("sim dispatch (1k coroutine steps)", || {
        let machine = Machine::new(Topology::milan_1s());
        arcas::sched::run_group(machine, Box::new(LocalCachePolicy), 8, |_| {
            Box::new(IterTask::new(125, |ctx, _| ctx.compute_ns(100)))
        })
        .dispatches
    });
    println!(
        "  => {:.1} M simulated dispatches/s",
        1000.0 / res.median_ns * 1e3
    );

    // --- Algorithm 2 placement map.
    b.bench("placement_map (128 ranks)", || {
        placement_map(&topo, 4, 128)
    });

    // --- host executor dispatch overhead.
    let pool = HostExecutor::new(4, &Topology::milan_1s(), false);
    let res = b.bench("host executor 1k no-op jobs", || {
        for _ in 0..1000 {
            pool.execute(|| {});
        }
        pool.wait_all();
    });
    println!(
        "  => {:.0} ns/job dispatch overhead",
        res.median_ns / 1000.0
    );

    // --- host *backend* through the engine seam: a full group run on
    // real threads (pool spawn + 100 coroutine steps + teardown), the
    // end-to-end cost `arcas run --backend host` pays per run.
    let res = b.bench("host backend group run (100 steps)", || {
        let machine = Machine::new(Topology::milan_1s());
        let (r, _) = arcas::engine::execute_on(
            arcas::engine::ExecBackend::Host,
            machine,
            Box::new(LocalCachePolicy),
            None,
            4,
            |_| Box::new(IterTask::new(25, |ctx, _| ctx.compute_ns(100))),
        );
        r.dispatches
    });
    println!(
        "  => {:.1} us/host-backed run (incl. pool spawn)",
        res.median_ns / 1e3
    );
}
