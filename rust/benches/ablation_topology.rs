//! Ablation: how much is chiplet-awareness worth on *different* machines?
//!
//! The paper's closing claim is that data-intensive systems must move
//! beyond NUMA-awareness *because of* chiplet partitioning. The clean
//! ablation: run the same workload suite under ARCAS vs the best
//! NUMA-aware baseline on
//!   - milan_2s      (the testbed: 2 x 8 chiplets),
//!   - genoa_1s      (more chiplets per socket: 12),
//!   - monolithic_64 (one unified LLC — chiplet-awareness should buy ~0).
//!
//! Expected shape: ARCAS's advantage grows with chiplet count and
//! vanishes on the monolithic die.

use std::sync::Arc;

use arcas::harness;
use arcas::topology::Topology;
use arcas::util::table::Table;
use arcas::workloads::graph::{self, kronecker::kronecker};
use arcas::workloads::streamcluster::{generate_points, run_streamcluster, ScConfig};

fn main() {
    let args = harness::bench_cli("ablation_topology", "chiplet-awareness vs machine").parse();
    harness::print_header(
        "Ablation: ARCAS advantage across machine generations",
        &args,
        &harness::bench_topology(&args),
    );
    let cache_scale = args.f64("cache-scale");
    let scale = ((16_777_216.0 * args.f64("scale")) as u64).max(1024).ilog2();

    let mut t = Table::new(
        "ARCAS speedup over NUMA-aware baseline, by machine",
        &["machine", "chiplets", "BFS vs RING", "SSSP vs RING", "StreamCluster vs Shoal"],
    );
    for preset in ["milan_2s", "genoa_1s", "monolithic_64"] {
        let topo = Topology::preset(preset).unwrap().scale_caches(cache_scale);
        let cores = 32.min(topo.num_cores());
        let g = Arc::new(kronecker(scale, 16, args.u64("seed")));
        let src = g.max_degree_vertex();

        let bfs_ring = graph::run_bfs(&topo, harness::baseline("ring", &topo), cores, g.clone(), src)
            .0
            .report
            .makespan_ns;
        let bfs_arcas = graph::run_bfs(&topo, harness::arcas(&topo, &args), cores, g.clone(), src)
            .0
            .report
            .makespan_ns;
        let sssp_ring =
            graph::run_sssp(&topo, harness::baseline("ring", &topo), cores, g.clone(), src)
                .0
                .report
                .makespan_ns;
        let sssp_arcas = graph::run_sssp(&topo, harness::arcas(&topo, &args), cores, g.clone(), src)
            .0
            .report
            .makespan_ns;

        // StreamCluster at 16 workers, batch ~5 chiplets' L3 (on the
        // monolithic machine that is just a fraction of the one LLC).
        let dims = 64usize;
        let batch =
            ((5 * topo.total_l3() / topo.num_chiplets() as u64) as usize / (dims * 4)).max(1024);
        let cfg = ScConfig {
            n_points: batch * 2,
            dims,
            batch_size: batch,
            k_min: 10,
            k_max: 20,
            max_centers: 5_000,
            local_iters: 3,
            seed: 7,
        };
        let pts = Arc::new(generate_points(&cfg));
        let sc_shoal = run_streamcluster(
            &topo,
            harness::baseline("shoal", &topo),
            16.min(topo.num_cores()),
            &cfg,
            pts.clone(),
        )
        .report
        .makespan_ns;
        let sc_arcas = run_streamcluster(
            &topo,
            harness::arcas(&topo, &args),
            16.min(topo.num_cores()),
            &cfg,
            pts,
        )
        .report
        .makespan_ns;

        t.row(vec![
            preset.to_string(),
            topo.num_chiplets().to_string(),
            format!("{:.2}x", bfs_ring as f64 / bfs_arcas as f64),
            format!("{:.2}x", sssp_ring as f64 / sssp_arcas as f64),
            format!("{:.2}x", sc_shoal as f64 / sc_arcas as f64),
        ]);
    }
    t.emit("ablation_topology");
    println!(
        "expected shape: speedups > 1 on chiplet machines, ~1.0 on the monolithic LLC\n\
         (chiplet-awareness is free when there is nothing to be aware of)"
    );
}
