//! Cluster-scaling figure: rps-at-p99 of a 4-shard machine fleet vs the
//! single machine on the `serve-cluster` hotspot-drift trace.
//!
//! The claim behind `--machines N`: key-sharded fan-out through the
//! cluster link tier buys serving capacity — four machines sustain a
//! higher offered rate at the same sojourn p99 budget than one, even
//! though ~3/4 of the traffic pays the cross-machine hop and the
//! drifting hotspot keeps forcing `plan_shard_moves` rebalances. Sim
//! backend only, so every number is deterministic and the CI gate can
//! pin the headline ratio (`ci/baselines/BENCH_cluster_scaling.json`).
//!
//! Method: per machine count N in {1, 4}, replay the drifted trace at a
//! x0.5..x4 ladder of offered rates and report the highest rate whose
//! merged sojourn p99 still fits `--p99-budget` (the `fig_serving`
//! throughput section, one tier up). Emits `BENCH_cluster_scaling.json`
//! with the per-N points and the gated `speedup_n4_vs_n1` headline.
//!
//! Flags beyond the standard set: `--requests N`, `--rate RPS`,
//! `--workers N`, `--p99-budget US`, `--drift-period US`,
//! `--assert-scaling` (fail unless the fleet beats the single machine).

use std::sync::Arc;

use arcas::engine::Run;
use arcas::harness;
use arcas::policy::Policy;
use arcas::topology::Topology;
use arcas::util::table::Table;
use arcas::workloads::oltp::OltpWorkload;
use arcas::workloads::serve::{ServeKvScenario, Trace, TraceConfig};

const MACHINES: [usize; 2] = [1, 4];
const LADDER: [f64; 4] = [0.5, 1.0, 2.0, 4.0];

fn main() {
    let args = harness::bench_cli(
        "fig_cluster",
        "serve-cluster rps-at-p99: 4 machine shards vs 1 behind the front end",
    )
    .opt("requests", "20000", "requests in the synthetic trace")
    .opt("rate", "4000000", "base offered load, requests/second of virtual time")
    .opt("workers", "16", "server worker count per machine shard")
    .opt("p99-budget", "300", "sojourn p99 budget, microseconds")
    .opt("drift-period", "500", "hotspot drift period, microseconds")
    .flag("assert-scaling", "exit non-zero unless 4 shards beat 1 machine")
    .parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("fig_cluster: key-sharded fleet scaling", &args, &topo);

    let requests = if args.flag("quick") {
        (args.usize("requests") / 5).max(1_000)
    } else {
        args.usize("requests")
    };
    let base_rate = args.f64("rate");
    let budget_us = args.f64("p99-budget");
    let budget_ns = (budget_us * 1_000.0) as u64;
    let drift_ns = (args.f64("drift-period") * 1_000.0) as u64;
    let workers = args.usize("workers").clamp(1, topo.num_cores());
    let OltpWorkload::Ycsb { records, read_frac } = OltpWorkload::ycsb_scaled(args.f64("scale"))
    else {
        unreachable!("ycsb_scaled always builds a Ycsb workload")
    };
    let keyspace = records as u64;
    println!(
        "# requests={requests} base={:.2}M rps budget={budget_us:.0}us \
         drift={}us workers/shard={workers} records={records}",
        base_rate / 1e6,
        drift_ns / 1_000,
    );

    // The serve-cluster trace shape: zipf-skewed keys whose hot range
    // walks a quarter of the keyspace every drift period, so the slot
    // the traffic concentrates on keeps changing shards' loads.
    let drifted = |rate_rps: f64| -> Arc<Trace> {
        Arc::new(
            Trace::synth(&TraceConfig {
                requests,
                rate_rps,
                keyspace,
                zipf_theta: 0.99,
                read_frac,
                seed: args.u64("seed"),
                ..Default::default()
            })
            .with_hotspot_drift(drift_ns, keyspace / 4 + 1, keyspace),
        )
    };
    // Every shard (and the front end) runs the adaptive policy; the
    // factory owns its captures so the run builder can hold it.
    let timer_ns = args.u64("timer-us") * 1_000;
    let topo2 = topo.clone();
    let shard_policy = move || -> Box<dyn Policy> {
        Box::new(arcas::policy::ArcasPolicy::new(&topo2).with_timer(timer_ns))
    };

    let mut tab = Table::new(
        "serve-cluster rps-at-p99 (sim): highest offered rate with merged p99 <= budget",
        &["machines", "rps_at_p99", "shard moves", "x-link hops", "ladder p99s (rate:ns)"],
    );
    let mut points: Vec<String> = Vec::new();
    let mut rps_at: Vec<(usize, f64)> = Vec::new();
    for n in MACHINES {
        let mut best_rps = 0.0_f64;
        let mut rung_p99s: Vec<String> = Vec::new();
        let (mut moves, mut hops) = (0u64, 0u64);
        for mult in LADDER {
            let rung_rate = base_rate * mult;
            let mut s = ServeKvScenario::new(records, drifted(rung_rate));
            let run = Run::new(&topo)
                .policy(shard_policy())
                .tasks(workers)
                .cluster(n)
                .cluster_policy(shard_policy.clone())
                .run(&mut s);
            let lat = run
                .report
                .request_latency
                .unwrap_or_else(|| panic!("n={n}@{rung_rate:.0}rps: no latency report"));
            assert_eq!(lat.count, requests as u64, "n={n} dropped requests");
            assert_eq!(run.report.machines, n, "cluster counters missing");
            rung_p99s.push(format!("{:.1}M:{}", rung_rate / 1e6, lat.p99_ns));
            moves = moves.max(run.report.shard_moves);
            hops = hops.max(run.report.cross_link_hops);
            if lat.p99_ns <= budget_ns && rung_rate > best_rps {
                best_rps = rung_rate;
            }
        }
        tab.row(vec![
            n.to_string(),
            format!("{best_rps:.0}"),
            moves.to_string(),
            hops.to_string(),
            rung_p99s.join(" "),
        ]);
        // `rps_at_p99` is 0 when no rung fits the budget — a pinned gate
        // then fails loudly instead of reporting a phantom speedup.
        points.push(format!(
            "    {{\"machines\": {n}, \"rps_at_p99\": {best_rps:.1}, \
             \"shard_moves\": {moves}, \"cross_link_hops\": {hops}}}"
        ));
        rps_at.push((n, best_rps));
    }
    tab.emit("fig_cluster");

    let rps1 = rps_at.iter().find(|(n, _)| *n == 1).map_or(0.0, |(_, r)| *r);
    let rps4 = rps_at.iter().find(|(n, _)| *n == 4).map_or(0.0, |(_, r)| *r);
    let speedup = if rps1 > 0.0 {
        format!("{:.3}", rps4 / rps1)
    } else {
        "null".to_string()
    };
    println!("# speedup_n4_vs_n1 = {speedup}");

    // "pinned": true so copying this file over ci/baselines/ (the
    // re-pin flow) yields a live gate, not another bootstrap placeholder.
    let json = format!(
        "{{\n  \"bench\": \"cluster_scaling\",\n  \"scenario\": \"serve-cluster\",\n  \
         \"pinned\": true,\n  \
         \"config\": {{\"requests\": {requests}, \"base_rate_rps\": {base_rate}, \
         \"workers\": {workers}, \"scale\": {}, \"seed\": {}, \"quick\": {}, \
         \"budget_us\": {budget_us}, \"drift_period_ns\": {drift_ns}, \
         \"ladder\": \"0.5,1,2,4\"}},\n  \
         \"points\": [\n{}\n  ],\n  \
         \"speedup_n4_vs_n1\": {speedup},\n  \"tol\": 0.25\n}}\n",
        args.f64("scale"),
        args.u64("seed"),
        args.flag("quick"),
        points.join(",\n")
    );
    let path = std::path::Path::new("BENCH_cluster_scaling.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!(
            "=> wrote {}",
            std::fs::canonicalize(path)
                .unwrap_or_else(|_| path.to_path_buf())
                .display()
        ),
        Err(e) => println!("=> could not write BENCH_cluster_scaling.json: {e}"),
    }

    if args.flag("assert-scaling") {
        assert!(
            rps1 > 0.0 && rps4 / rps1 > 1.0,
            "4 shards must beat 1 machine on rps-at-p99 (n1={rps1:.0}, n4={rps4:.0})"
        );
        println!("# assert-scaling: ok (n4/n1 = {:.3})", rps4 / rps1);
    }
}
