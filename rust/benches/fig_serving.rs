//! Serving-latency figure: request sojourn distribution (p50/p95/p99 +
//! CDF) of the `serve-kv` open-loop trace replay, per policy × backend.
//!
//! This is the repo's tail-latency lens: every scheduling heuristic
//! becomes a measurable p99 here instead of a makespan. Sim-backend
//! series are deterministic (the CI bench-regression gate pins their
//! p99 against `ci/baselines/BENCH_serving_latency.json`); host-backend
//! series add real-thread interleaving on the same virtual cost model
//! and are gated with a loose band.
//!
//! Emits `BENCH_serving_latency.json`:
//! `{"series": [{"policy", "backend", "p50_ns", ..., "cdf": [[ns, frac], ...]}]}`
//! plus `BENCH_serving_slo.json` from the SLO section: a prioritized
//! trace driven past capacity per policy (sim only), gating per-class
//! p99s and the Background shed rate via the `"metric"` key, and
//! `BENCH_serving_throughput.json` from the throughput section: per
//! policy, the highest offered rate on a x0.5..x4 ladder whose sojourn
//! p99 still fits `--p99-budget` (sim only, `higher_is_better` so the
//! gate fails on throughput loss, not gain).
//!
//! Flags beyond the standard set: `--requests N`, `--rate RPS`,
//! `--arrivals poisson|uniform|diurnal|bursty`, `--workers N`,
//! `--policies a,b,c`, `--slo-rate RPS`, `--slo-budget US`,
//! `--p99-budget US`.

use std::sync::Arc;

use arcas::engine::{ExecBackend, Run};
use arcas::harness;
use arcas::policy::Policy;
use arcas::topology::Topology;
use arcas::util::cli::Args;
use arcas::util::json::escape;
use arcas::util::stats::LogHistogram;
use arcas::util::table::Table;
use arcas::workloads::oltp::OltpWorkload;
use arcas::workloads::serve::{
    ArrivalModel, PriorityMix, ServeKvScenario, ServeOpts, Trace, TraceConfig,
};

struct Series {
    policy: String,
    backend: ExecBackend,
    lat: arcas::sched::LatencyReport,
    hist: LogHistogram,
}

fn policy_by_name(name: &str, topo: &Topology, args: &Args) -> Box<dyn Policy> {
    if name == "arcas" {
        harness::arcas(topo, args)
    } else {
        harness::baseline(name, topo)
    }
}

fn main() {
    let args = harness::bench_cli("fig_serving", "serve-kv sojourn latency per policy x backend")
        .opt("requests", "20000", "requests in the synthetic trace")
        .opt("rate", "4000000", "offered load, requests/second of virtual time")
        .opt("arrivals", "poisson", "arrival process: poisson|uniform|diurnal|bursty")
        .opt("workers", "16", "server worker count")
        .opt("policies", "local,distributed,arcas", "comma-separated policy list")
        .opt("slo-rate", "8000000", "offered load of the SLO overload section, requests/second")
        .opt("slo-budget", "150", "queue-wait SLO budget of the overload section, microseconds")
        .opt("p99-budget", "300", "sojourn p99 budget of the throughput section, microseconds")
        .parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("fig_serving: open-loop serve-kv latency", &args, &topo);

    let requests = if args.flag("quick") {
        (args.usize("requests") / 5).max(1_000)
    } else {
        args.usize("requests")
    };
    let rate = args.f64("rate");
    let arrivals = match args.str("arrivals").as_str() {
        "poisson" => ArrivalModel::Poisson,
        "uniform" => ArrivalModel::Uniform,
        "diurnal" => ArrivalModel::Diurnal {
            period_ns: 2_000_000,
            depth: 0.8,
        },
        "bursty" => ArrivalModel::Bursty { burst: 64 },
        other => panic!("unknown --arrivals {other} (poisson|uniform|diurnal|bursty)"),
    };
    let OltpWorkload::Ycsb { records, read_frac } = OltpWorkload::ycsb_scaled(args.f64("scale"))
    else {
        unreachable!("ycsb_scaled always builds a Ycsb workload")
    };
    let trace = Arc::new(Trace::synth(&TraceConfig {
        requests,
        rate_rps: rate,
        keyspace: records as u64,
        zipf_theta: 0.99,
        read_frac,
        arrivals,
        seed: args.u64("seed"),
        priority_mix: None,
    }));
    let workers = args.usize("workers").clamp(1, topo.num_cores());
    println!(
        "# requests={requests} offered={:.2}M rps arrivals={} workers={workers} records={records}",
        trace.offered_rate_rps() / 1e6,
        args.str("arrivals"),
    );

    let policies: Vec<String> = args
        .str("policies")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().to_string())
        .collect();
    let mut series: Vec<Series> = Vec::new();
    for policy in &policies {
        for backend in ExecBackend::ALL {
            let mut s = ServeKvScenario::new(records, trace.clone());
            let run = Run::new(&topo)
                .policy(policy_by_name(policy, &topo, &args))
                .tasks(workers)
                .backend(backend)
                .verify(true)
                .run(&mut s);
            let lat = run
                .report
                .request_latency
                .unwrap_or_else(|| panic!("{policy}/{backend}: no latency report"));
            assert_eq!(lat.count, requests as u64, "{policy}/{backend} dropped requests");
            series.push(Series {
                policy: policy.clone(),
                backend,
                lat,
                hist: s.latency_histogram().expect("histogram after run"),
            });
        }
    }

    // Table: the tail per policy × backend.
    let mut tab = Table::new(
        "serve-kv request sojourn (ns)",
        &["policy", "backend", "p50", "p95", "p99", "max", "mean queue", "mean service"],
    );
    for s in &series {
        tab.row(vec![
            s.policy.clone(),
            s.backend.to_string(),
            format!("{}", s.lat.p50_ns),
            format!("{}", s.lat.p95_ns),
            format!("{}", s.lat.p99_ns),
            format!("{}", s.lat.max_ns),
            format!("{:.0}", s.lat.mean_queue_ns),
            format!("{:.0}", s.lat.mean_service_ns),
        ]);
    }
    tab.emit("fig_serving");

    // Sim determinism sanity: both sim runs of the same policy would be
    // identical; at least require ordered quantiles everywhere.
    for s in &series {
        assert!(
            s.lat.p50_ns <= s.lat.p95_ns
                && s.lat.p95_ns <= s.lat.p99_ns
                && s.lat.p99_ns <= s.lat.max_ns,
            "{}/{}: quantiles out of order",
            s.policy,
            s.backend
        );
    }

    // Emit BENCH_serving_latency.json for the CI regression gate.
    let json_series: Vec<String> = series
        .iter()
        .map(|s| {
            // Downsample the CDF to <= 48 points for the artifact.
            let pts = s.hist.cdf_points();
            let stride = pts.len().div_ceil(48).max(1);
            let cdf: Vec<String> = pts
                .iter()
                .enumerate()
                .filter(|(i, _)| i % stride == 0 || *i == pts.len() - 1)
                .map(|(_, (ns, frac))| format!("[{ns}, {frac:.6}]"))
                .collect();
            // Each series carries its gate tolerance so re-pinning the
            // baseline (copying this file over ci/baselines/) preserves
            // the bands: sim is deterministic (tight), host sees real
            // thread interleaving on shared runners (loose).
            let tol = match s.backend {
                ExecBackend::Sim => 0.05,
                ExecBackend::Host => 0.50,
            };
            format!(
                "    {{\"policy\": \"{}\", \"backend\": \"{}\", \"count\": {}, \
                 \"p50_ns\": {}, \"p95_ns\": {}, \"p99_ns\": {}, \"max_ns\": {}, \
                 \"mean_queue_ns\": {:.1}, \"mean_service_ns\": {:.1}, \"tol\": {tol}, \
                 \"cdf\": [{}]}}",
                escape(&s.policy),
                s.backend,
                s.lat.count,
                s.lat.p50_ns,
                s.lat.p95_ns,
                s.lat.p99_ns,
                s.lat.max_ns,
                s.lat.mean_queue_ns,
                s.lat.mean_service_ns,
                cdf.join(", ")
            )
        })
        .collect();
    // "pinned": true so copying this file over ci/baselines/ (the re-pin
    // flow) yields a live gate, not another bootstrap placeholder.
    let json = format!(
        "{{\n  \"bench\": \"serving_latency\",\n  \"scenario\": \"serve-kv\",\n  \
         \"pinned\": true,\n  \
         \"config\": {{\"requests\": {requests}, \"rate_rps\": {rate}, \"arrivals\": \"{}\", \
         \"workers\": {workers}, \"scale\": {}, \"seed\": {}, \"quick\": {}}},\n  \
         \"series\": [\n{}\n  ]\n}}\n",
        escape(&args.str("arrivals")),
        args.f64("scale"),
        args.u64("seed"),
        args.flag("quick"),
        json_series.join(",\n")
    );
    let path = std::path::Path::new("BENCH_serving_latency.json");
    match std::fs::write(path, &json) {
        Ok(()) => println!(
            "=> wrote {}",
            std::fs::canonicalize(path)
                .unwrap_or_else(|_| path.to_path_buf())
                .display()
        ),
        Err(e) => println!("=> could not write BENCH_serving_latency.json: {e}"),
    }

    // ---- SLO section: priority tiers + shedding past capacity (sim) ----
    // Same workload shape, driven at `--slo-rate` (past capacity) with a
    // critical/background tenant mix and a queue-wait budget. Sim only:
    // the series are deterministic, so per-class tails and the shed rate
    // gate tightly in CI (`BENCH_serving_slo.json`).
    let slo_rate = args.f64("slo-rate");
    let slo_budget_ns = (args.f64("slo-budget") * 1_000.0) as u64;
    let slo_trace = Arc::new(Trace::synth(&TraceConfig {
        requests,
        rate_rps: slo_rate,
        keyspace: records as u64,
        zipf_theta: 0.99,
        read_frac,
        arrivals,
        seed: args.u64("seed"),
        priority_mix: Some(PriorityMix {
            critical: 0.2,
            background: 0.3,
        }),
    }));
    let mut slo_tab = Table::new(
        "serve-kv SLO section (sim, past capacity): per-class p99 (ns) + shed rate",
        &["policy", "critical p99", "normal p99", "background p99", "shed rate"],
    );
    let mut slo_entries: Vec<String> = Vec::new();
    for policy in &policies {
        let mut s = ServeKvScenario::new(records, slo_trace.clone()).with_opts(ServeOpts {
            slo_shed_ns: Some(slo_budget_ns),
            closed_loop_think_ns: None,
        });
        let run = Run::new(&topo)
            .policy(policy_by_name(policy, &topo, &args))
            .tasks(workers)
            .verify(true)
            .run(&mut s);
        let shed_rate = run.report.request_shed as f64 / requests as f64;
        let p99_of = |class: &str| {
            run.report
                .class_latency
                .iter()
                .find(|(n, _)| *n == class)
                .map(|(_, l)| l.p99_ns)
        };
        slo_tab.row(vec![
            policy.clone(),
            p99_of("critical").map_or("-".into(), |v| v.to_string()),
            p99_of("normal").map_or("-".into(), |v| v.to_string()),
            p99_of("background").map_or("-".into(), |v| v.to_string()),
            format!("{shed_rate:.4}"),
        ]);
        for (class, l) in &run.report.class_latency {
            slo_entries.push(format!(
                "    {{\"policy\": \"{}\", \"backend\": \"sim\", \"metric\": \"{class}_p99_ns\", \
                 \"{class}_p99_ns\": {}, \"count\": {}, \"tol\": 0.05}}",
                escape(policy),
                l.p99_ns,
                l.count,
            ));
        }
        slo_entries.push(format!(
            "    {{\"policy\": \"{}\", \"backend\": \"sim\", \"metric\": \"shed_rate\", \
             \"shed_rate\": {shed_rate:.6}, \"tol\": 0.10}}",
            escape(policy),
        ));
    }
    slo_tab.emit("fig_serving_slo");

    let slo_json = format!(
        "{{\n  \"bench\": \"serving_slo\",\n  \"scenario\": \"serve-kv\",\n  \
         \"pinned\": true,\n  \
         \"config\": {{\"requests\": {requests}, \"rate_rps\": {slo_rate}, \"arrivals\": \"{}\", \
         \"workers\": {workers}, \"scale\": {}, \"seed\": {}, \"quick\": {}, \
         \"budget_us\": {}, \"mix\": \"0.2,0.3\"}},\n  \
         \"series\": [\n{}\n  ]\n}}\n",
        escape(&args.str("arrivals")),
        args.f64("scale"),
        args.u64("seed"),
        args.flag("quick"),
        args.f64("slo-budget"),
        slo_entries.join(",\n")
    );
    let slo_path = std::path::Path::new("BENCH_serving_slo.json");
    match std::fs::write(slo_path, &slo_json) {
        Ok(()) => println!(
            "=> wrote {}",
            std::fs::canonicalize(slo_path)
                .unwrap_or_else(|_| slo_path.to_path_buf())
                .display()
        ),
        Err(e) => println!("=> could not write BENCH_serving_slo.json: {e}"),
    }

    // ---- Throughput section: requests/sec at a fixed p99 budget (sim) ----
    // The latency series above pin a tail at one offered rate; this section
    // pins capacity: per policy, replay the trace at a x0.5..x4 ladder of
    // offered rates and report the highest rate whose sojourn p99 still
    // fits `--p99-budget`. Sim only, so the number is deterministic and
    // the CI gate can hold a throughput floor (`higher_is_better`) instead
    // of asserting a speedup at bench time.
    let budget_us = args.f64("p99-budget");
    let budget_ns = (budget_us * 1_000.0) as u64;
    const LADDER: [f64; 4] = [0.5, 1.0, 2.0, 4.0];
    let mut tp_tab = Table::new(
        "serve-kv throughput (sim): highest offered rate with sojourn p99 <= budget",
        &["policy", "budget (us)", "rps_at_p99", "ladder p99s (rate:ns)"],
    );
    let mut tp_entries: Vec<String> = Vec::new();
    for policy in &policies {
        let mut best_rps = 0.0_f64;
        let mut rung_p99s: Vec<String> = Vec::new();
        for mult in LADDER {
            let rung_rate = rate * mult;
            let rung_trace = Arc::new(Trace::synth(&TraceConfig {
                requests,
                rate_rps: rung_rate,
                keyspace: records as u64,
                zipf_theta: 0.99,
                read_frac,
                arrivals,
                seed: args.u64("seed"),
                priority_mix: None,
            }));
            let mut s = ServeKvScenario::new(records, rung_trace);
            let run = Run::new(&topo)
                .policy(policy_by_name(policy, &topo, &args))
                .tasks(workers)
                .verify(true)
                .run(&mut s);
            let lat = run
                .report
                .request_latency
                .unwrap_or_else(|| panic!("{policy}@{rung_rate:.0}rps: no latency report"));
            rung_p99s.push(format!("{:.1}M:{}", rung_rate / 1e6, lat.p99_ns));
            if lat.p99_ns <= budget_ns && rung_rate > best_rps {
                best_rps = rung_rate;
            }
        }
        tp_tab.row(vec![
            policy.clone(),
            format!("{budget_us:.0}"),
            format!("{best_rps:.0}"),
            rung_p99s.join(" "),
        ]);
        // `rps_at_p99` is 0 when no rung fits the budget — a pinned gate
        // then fails loudly instead of silently skipping the policy.
        tp_entries.push(format!(
            "    {{\"policy\": \"{}\", \"backend\": \"sim\", \"metric\": \"rps_at_p99\", \
             \"rps_at_p99\": {best_rps:.1}, \"higher_is_better\": true, \"tol\": 0.05}}",
            escape(policy),
        ));
    }
    tp_tab.emit("fig_serving_throughput");

    let tp_json = format!(
        "{{\n  \"bench\": \"serving_throughput\",\n  \"scenario\": \"serve-kv\",\n  \
         \"pinned\": true,\n  \
         \"config\": {{\"requests\": {requests}, \"base_rate_rps\": {rate}, \"arrivals\": \"{}\", \
         \"workers\": {workers}, \"scale\": {}, \"seed\": {}, \"quick\": {}, \
         \"budget_us\": {budget_us}, \"ladder\": \"0.5,1,2,4\"}},\n  \
         \"series\": [\n{}\n  ]\n}}\n",
        escape(&args.str("arrivals")),
        args.f64("scale"),
        args.u64("seed"),
        args.flag("quick"),
        tp_entries.join(",\n")
    );
    let tp_path = std::path::Path::new("BENCH_serving_throughput.json");
    match std::fs::write(tp_path, &tp_json) {
        Ok(()) => println!(
            "=> wrote {}",
            std::fs::canonicalize(tp_path)
                .unwrap_or_else(|_| tp_path.to_path_buf())
                .display()
        ),
        Err(e) => println!("=> could not write BENCH_serving_throughput.json: {e}"),
    }
}
