//! Fig. 13 reproduction: OLTP commits/s under LocalCache vs
//! DistributedCache scheduling (ERMIA-style engine), YCSB (a) and TPC-C
//! (b), across core counts.
//!
//! Paper shape: a *null* result — the two policies are nearly identical
//! at every core count, because OLTP is commit/synchronization-bound.

use arcas::harness;
use arcas::util::table::SeriesSet;
use arcas::workloads::oltp::{run_oltp, OltpWorkload};

fn main() {
    let args = harness::bench_cli("fig13_oltp", "OLTP Local vs Distributed").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Fig 13: OLTP commits/s", &args, &topo);

    let txns: u64 = if args.flag("quick") { 5_000 } else { 20_000 };
    let cores = harness::core_sweep(&args, &[4, 8, 16, 32, 64]);
    let workloads = [
        (
            "a: YCSB",
            OltpWorkload::ycsb_scaled(args.f64("scale")),
            "fig13a_ycsb",
        ),
        (
            "b: TPC-C",
            OltpWorkload::tpcc_scaled(args.f64("scale") * 50.0),
            "fig13b_tpcc",
        ),
    ];

    for (label, wl, slug) in workloads {
        let mut series = SeriesSet::new(
            &format!("Fig 13{label}: commits/s"),
            "cores",
            &["LocalCache", "DistributedCache"],
        );
        let mut max_dev: f64 = 0.0;
        for &c in &cores {
            if c > topo.num_cores() {
                continue;
            }
            let local = run_oltp(
                &topo,
                harness::baseline("local", &topo),
                c,
                &wl,
                txns,
                args.u64("seed"),
            );
            let dist = run_oltp(
                &topo,
                harness::baseline("distributed", &topo),
                c,
                &wl,
                txns,
                args.u64("seed"),
            );
            let (l, d) = (local.commits_per_sec(), dist.commits_per_sec());
            max_dev = max_dev.max((l / d - 1.0).abs());
            println!(
                "{label} cores {c:>3}: Local {l:>12.0}  Distributed {d:>12.0}  ({:+.1}%)",
                (l / d - 1.0) * 100.0
            );
            series.point(c as f64, vec![l, d]);
        }
        series.emit(slug);
        println!(
            "{label}: max policy deviation {:.1}% (paper: nearly identical)\n",
            max_dev * 100.0
        );
    }
}
