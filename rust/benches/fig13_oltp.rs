//! Fig. 13 reproduction: OLTP commits/s under LocalCache vs
//! DistributedCache scheduling (ERMIA-style engine), YCSB (a) and TPC-C
//! (b), across core counts.
//!
//! Paper shape: a *null* result — the two policies are nearly identical
//! at every core count, because OLTP is commit/synchronization-bound.
//!
//! The workloads come from the scenario registry and run through
//! `engine::Run` — the same code path `arcas run --scenario ycsb`
//! takes.

use arcas::engine::Run;
use arcas::harness;
use arcas::util::table::SeriesSet;

fn main() {
    let args = harness::bench_cli("fig13_oltp", "OLTP Local vs Distributed").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Fig 13: OLTP commits/s", &args, &topo);

    let txns: u64 = if args.flag("quick") { 5_000 } else { 20_000 };
    let cores = harness::core_sweep(&args, &[4, 8, 16, 32, 64]);
    let workloads = [
        ("a: YCSB", "ycsb", 1.0, "fig13a_ycsb"),
        ("b: TPC-C", "tpcc", 50.0, "fig13b_tpcc"),
    ];

    for (label, scenario, scale_mul, slug) in workloads {
        let mut params = harness::scenario_params(&args);
        params.scale *= scale_mul;
        params.iters = Some(txns);
        let mut series = SeriesSet::new(
            &format!("Fig 13{label}: commits/s"),
            "cores",
            &["LocalCache", "DistributedCache"],
        );
        let mut max_dev: f64 = 0.0;
        for &c in &cores {
            if c > topo.num_cores() {
                continue;
            }
            let run_one = |policy: &str| {
                let mut s = harness::scenario_with(scenario, &params);
                Run::new(&topo)
                    .policy(harness::baseline(policy, &topo))
                    .tasks(c)
                    .run(s.as_mut())
            };
            let local = run_one("local");
            let dist = run_one("distributed");
            let (l, d) = (local.throughput(), dist.throughput());
            max_dev = max_dev.max((l / d - 1.0).abs());
            println!(
                "{label} cores {c:>3}: Local {l:>12.0}  Distributed {d:>12.0}  ({:+.1}%)",
                (l / d - 1.0) * 100.0
            );
            series.point(c as f64, vec![l, d]);
        }
        series.emit(slug);
        println!(
            "{label}: max policy deviation {:.1}% (paper: nearly identical)\n",
            max_dev * 100.0
        );
    }
}
