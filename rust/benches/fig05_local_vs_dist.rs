//! Fig. 5 reproduction: LocalCache vs DistributedCache write µbenchmark.
//!
//! 8 cores write a shared vector in per-core chunks, repeated over many
//! iterations, with the vector size swept across the cache hierarchy.
//! Paper result: LocalCache wins below one chiplet's L3 capacity; beyond
//! it DistributedCache wins, peaking at ~2.5× for DRAM-resident sizes.
//! Speedup plotted is t(LocalCache)/t(DistributedCache).

use std::sync::Arc;

use arcas::harness;
use arcas::mem::Placement;
use arcas::policy::{DistributedCachePolicy, LocalCachePolicy, Policy};
use arcas::sim::Machine;
use arcas::task::BspTask;
use arcas::topology::Topology;
use arcas::util::table::SeriesSet;

const CORES: usize = 8;

fn run_one(
    topo: &Topology,
    backend: arcas::engine::ExecBackend,
    policy: Box<dyn Policy>,
    size: u64,
    iters: u64,
) -> u64 {
    let machine = Machine::new(topo.clone());
    // Per-core chunk regions of the shared vector.
    let chunk = (size / CORES as u64).max(64);
    let regions: Vec<_> = (0..CORES)
        .map(|r| machine.alloc(&format!("chunk-{r}"), chunk, Placement::Interleave))
        .collect();
    let regions = Arc::new(regions);
    // Executor boilerplate lives in the engine layer now; `--backend
    // host` replays the same sweep on real threads.
    arcas::engine::Run::on_machine(machine)
        .policy(policy)
        .backend(backend)
        .tasks(CORES)
        .run_group(|rank| {
            let regions = regions.clone();
            Box::new(BspTask::new(iters, move |ctx, _| {
                ctx.seq_write(regions[rank], chunk);
                // Per-iteration reduction to rank 0 — the coordination
                // step of the real µbenchmark. Intra-chiplet for
                // LocalCache, cross-chiplet for DistributedCache: the
                // reason LocalCache wins while the vector fits one
                // chiplet's L3 (paper: down to 0.59x).
                if rank != 0 {
                    let core = ctx.core;
                    ctx.machine.message(core, 0, 64);
                }
            }))
        })
        .0
        .makespan_ns
}

fn main() {
    let args = harness::with_backend_opt(harness::bench_cli(
        "fig05_local_vs_dist",
        "LocalCache vs DistributedCache write sweep",
    ))
    .parse();
    let topo = harness::bench_topology(&args);
    let backend = harness::backend(&args);
    harness::print_header("Fig 5: LocalCache vs DistributedCache", &args, &topo);
    let l3 = topo.l3_per_chiplet;
    println!("# L3/chiplet = {}", arcas::util::fmt_bytes(l3));

    // Sweep sizes across the hierarchy like the paper's 38 B .. 38 GB:
    // from tiny to 64x one chiplet's L3.
    let sizes: Vec<u64> = (0..12)
        .map(|i| (l3 / 128) << i) // l3/128 .. 16*l3
        .collect();
    let iters = if args.flag("quick") { 20 } else { 100 };

    let mut series = SeriesSet::new(
        "Fig 5: write speedup Local/Distributed (>1 = DistributedCache wins)",
        "size_bytes",
        &["speedup", "local_ms", "dist_ms"],
    );
    let mut crossover = None;
    for &size in &sizes {
        let t_local = run_one(&topo, backend, Box::new(LocalCachePolicy), size, iters);
        let t_dist = run_one(&topo, backend, Box::new(DistributedCachePolicy), size, iters);
        let speedup = t_local as f64 / t_dist as f64;
        if speedup > 1.0 && crossover.is_none() {
            crossover = Some(size);
        }
        println!(
            "size {:>12} local {:>10} dist {:>10} speedup {:.2}x",
            arcas::util::fmt_bytes(size),
            arcas::util::fmt_ns(t_local),
            arcas::util::fmt_ns(t_dist),
            speedup
        );
        series.point(
            size as f64,
            vec![speedup, t_local as f64 / 1e6, t_dist as f64 / 1e6],
        );
    }
    series.emit("fig05_local_vs_dist");

    match crossover {
        Some(s) => println!(
            "crossover at {} (paper: ~32 MB = one chiplet's L3; here L3/chiplet = {})",
            arcas::util::fmt_bytes(s),
            arcas::util::fmt_bytes(l3)
        ),
        None => println!("no crossover observed in sweep"),
    }
    let last = series.points.last().unwrap().1[0];
    println!(
        "largest-size speedup: {last:.2}x (paper: 2.50x at 38 GB; range 0.59x-2.50x)"
    );
}
