//! Fig. 7 reproduction: graph processing + RandomAccess scalability,
//! ARCAS vs RING, cores 1..128.
//!
//! Six panels: BFS, PR, CC, SSSP, GUPS, Graph500. The paper reports
//! near-linear ARCAS scaling with the gap to RING widening at high core
//! counts (headline speedups 1.8x / 1.9x / 2.3x on BFS / CC / SSSP).

use std::sync::Arc;

use arcas::harness;
use arcas::util::table::SeriesSet;
use arcas::workloads::graph::{self, kronecker::kronecker};

fn main() {
    let args = harness::bench_cli("fig07_graph_scaling", "graph scalability vs RING").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Fig 7: graph + GUPS scalability", &args, &topo);

    // Paper: 2^24 vertices, ef 16 (~4 GB). Scaled: 2^24 * scale.
    let scale_f = args.f64("scale");
    let scale = ((16_777_216.0 * scale_f) as u64).max(1024).ilog2();
    let seed = args.u64("seed");
    let g = Arc::new(kronecker(scale, 16, seed));
    println!(
        "# graph: 2^{scale} vertices, {} edges, {}",
        g.num_edges(),
        arcas::util::fmt_bytes(g.bytes())
    );
    let cores = harness::core_sweep(&args, &[1, 2, 4, 8, 16, 32, 64, 128]);
    let src = g.max_degree_vertex();
    let src2 = g.neighbors(src).first().copied().unwrap_or(src);

    let algos: Vec<(&str, Box<dyn Fn(&arcas::topology::Topology, Box<dyn arcas::policy::Policy>, usize) -> f64>)> = vec![
        ("BFS", Box::new({
            let g = g.clone();
            move |t, p, c| graph::run_bfs(t, p, c, g.clone(), src).0.teps()
        })),
        ("PR", Box::new({
            let g = g.clone();
            move |t, p, c| graph::run_pagerank(t, p, c, g.clone(), 5).0.teps()
        })),
        ("CC", Box::new({
            let g = g.clone();
            move |t, p, c| graph::run_cc(t, p, c, g.clone()).0.teps()
        })),
        ("SSSP", Box::new({
            let g = g.clone();
            move |t, p, c| graph::run_sssp(t, p, c, g.clone(), src).0.teps()
        })),
        ("GUPS", Box::new({
            let words = (g.num_vertices() * 4) as usize;
            move |t, p, c| {
                graph::run_gups(t, p, c, words, 50_000, 7).0.teps()
            }
        })),
        ("Graph500", Box::new({
            let g = g.clone();
            move |t, p, c| {
                // Graph500: BFS from a random non-isolated root, TEPS.
                graph::run_bfs(t, p, c, g.clone(), src2).0.teps()
            }
        })),
    ];

    let mut headline = Vec::new();
    for (name, run) in &algos {
        let mut series = SeriesSet::new(
            &format!("Fig 7 [{name}]: throughput (M items/s)"),
            "cores",
            &["ARCAS", "RING"],
        );
        let mut last_ratio = 1.0;
        for &c in &cores {
            if c > topo.num_cores() {
                continue;
            }
            let a = run(&topo, harness::arcas(&topo, &args), c) / 1e6;
            let r = run(&topo, harness::baseline("ring", &topo), c) / 1e6;
            last_ratio = a / r.max(1e-12);
            series.point(c as f64, vec![a, r]);
        }
        series.emit(&format!("fig07_{}", name.to_lowercase()));
        println!("{name}: ARCAS/RING at max cores = {last_ratio:.2}x\n");
        headline.push((name, last_ratio));
    }
    println!("== Fig 7 headline (paper: BFS 1.8x, CC 1.9x, SSSP 2.3x at 128 cores) ==");
    for (name, r) in headline {
        println!("  {name:<9} {r:.2}x");
    }
}
