//! Fig. 3 reproduction: CDF of core-to-core latency on dual-socket Milan.
//!
//! The paper measures ping-pong latency for three scenarios: Within
//! Chiplet, Within NUMA (which shows the 3-step structure: ~25 ns
//! intra-chiplet, ~85 ns near group, ≥150 ns far group) and Cross NUMA.
//! Here the samples come from the calibrated topology model's all-pairs
//! latency (with the simulator's message path adding queue effects).

use arcas::harness;
use arcas::topology::{LatencyClass, Topology};
use arcas::util::stats::Cdf;
use arcas::util::table::Table;

fn main() {
    let args = harness::bench_cli("fig03_latency_cdf", "core-to-core latency CDF").parse();
    let topo = Topology::preset(&args.str("topology")).unwrap_or_else(Topology::milan_2s);
    harness::print_header("Fig 3: core-to-core latency CDF", &args, &topo);

    let n = topo.num_cores();
    let mut within_chiplet = Vec::new();
    let mut within_numa = Vec::new();
    let mut cross_numa = Vec::new();
    for a in 0..n {
        for b in (a + 1)..n {
            let ns = topo.core_to_core_ns(a, b);
            match topo.latency_class(a, b) {
                LatencyClass::SameCore => {}
                LatencyClass::IntraChiplet => {
                    within_chiplet.push(ns);
                    within_numa.push(ns);
                }
                LatencyClass::InterChipletNear | LatencyClass::InterChipletFar => {
                    within_numa.push(ns);
                }
                LatencyClass::CrossNuma | LatencyClass::CrossSocket => cross_numa.push(ns),
            }
        }
    }

    let mut t = Table::new(
        "Fig 3: latency CDF (ns at percentile)",
        &["percentile", "Within Chiplet", "Within NUMA", "Cross NUMA"],
    );
    let cdfs = [
        Cdf::from_samples(&within_chiplet),
        Cdf::from_samples(&within_numa),
        Cdf::from_samples(&cross_numa),
    ];
    for q in [0.01, 0.10, 0.25, 0.50, 0.75, 0.90, 0.99, 1.0] {
        let mut row = vec![format!("p{:.0}", q * 100.0)];
        for c in &cdfs {
            row.push(format!("{:.0}", c.quantile(q)));
        }
        t.row(row);
    }
    t.emit("fig03_latency_cdf");

    // The 3-step structure within a NUMA domain (the paper's key point).
    let wn = Cdf::from_samples(&within_numa);
    println!(
        "within-NUMA steps: {:.0} ns ({:.0}%), {:.0} ns ({:.0}%), {:.0} ns (rest)",
        wn.quantile(0.05),
        wn.at(30.0) * 100.0,
        wn.quantile(0.5),
        (wn.at(100.0) - wn.at(30.0)) * 100.0,
        wn.quantile(0.95),
    );
    assert!(wn.quantile(0.05) < 35.0);
    assert!(wn.quantile(0.95) > 140.0);
    println!("OK: within-NUMA latency is heterogeneous (3 groups), matching Fig. 3");
}
