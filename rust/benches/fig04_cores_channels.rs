//! Fig. 4 reproduction: server CPU cores vs memory channels, 2010–2026.
//!
//! Curated public vendor data (the figure's point is the widening
//! cores-per-channel gap that motivates cache-aware scheduling).

use arcas::harness;
use arcas::util::table::Table;

fn main() {
    let mut t = Table::new(
        "Fig 4: cores vs memory channels over the years",
        &["year", "cpu", "cores", "mem channels", "cores/channel"],
    );
    let rows = harness::cores_vs_channels();
    for (year, cpu, cores, ch) in &rows {
        t.row(vec![
            year.to_string(),
            cpu.to_string(),
            cores.to_string(),
            ch.to_string(),
            format!("{:.1}", *cores as f64 / *ch as f64),
        ]);
    }
    t.emit("fig04_cores_channels");

    let first = rows[0].2 as f64 / rows[0].3 as f64;
    let last = rows.last().unwrap().2 as f64 / rows.last().unwrap().3 as f64;
    println!(
        "cores-per-channel grew {:.1}x ({}->{}): the bandwidth wall the paper motivates",
        last / first,
        rows[0].0,
        rows.last().unwrap().0
    );
}
