//! Tab. 1 reproduction: chiplet access counters (×10³) at 64 cores,
//! ARCAS vs RING, across the six graph benchmarks.
//!
//! Paper shape: ARCAS's remote-NUMA-chiplet accesses are orders of
//! magnitude below RING's, while its local-chiplet hits are higher —
//! chiplet-aware placement converts remote L3 traffic into local hits.

use std::sync::Arc;

use arcas::harness;
use arcas::util::table::Table;
use arcas::workloads::graph::{self, kronecker::kronecker};

fn main() {
    let args = harness::bench_cli("tab1_chiplet_accesses", "Tab 1: access counters").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Tab 1: chiplet accesses @64 cores", &args, &topo);
    let cores = 64.min(topo.num_cores());
    let scale = ((16_777_216.0 * args.f64("scale")) as u64).max(1024).ilog2();
    let g = Arc::new(kronecker(scale, 16, args.u64("seed")));
    let src = g.max_degree_vertex();

    let mut t = Table::new(
        "Tab 1: chiplet accesses (x10^3), 64 cores",
        &[
            "Application",
            "RemoteNUMA ARCAS",
            "RemoteNUMA RING",
            "LocalChiplet ARCAS",
            "LocalChiplet RING",
        ],
    );
    let run = |name: &str, policy: Box<dyn arcas::policy::Policy>| -> (f64, f64) {
        let report = match name {
            "BFS" => graph::run_bfs(&topo, policy, cores, g.clone(), src).0.report,
            "PR" => graph::run_pagerank(&topo, policy, cores, g.clone(), 5).0.report,
            "CC" => graph::run_cc(&topo, policy, cores, g.clone()).0.report,
            "SSSP" => graph::run_sssp(&topo, policy, cores, g.clone(), src).0.report,
            "GUPS" => {
                graph::run_gups(&topo, policy, cores, g.num_vertices() * 4, 50_000, 7)
                    .0
                    .report
            }
            _ => graph::run_bfs(&topo, policy, cores, g.clone(), src).0.report,
        };
        (report.counts.far / 1e3, report.counts.local / 1e3)
    };

    let mut ratios = Vec::new();
    for name in ["BFS", "PR", "CC", "SSSP", "GUPS", "Graph500"] {
        let (a_far, a_local) = run(name, harness::arcas(&topo, &args));
        let (r_far, r_local) = run(name, harness::baseline("ring", &topo));
        t.row(vec![
            name.to_string(),
            format!("{:.0}", a_far),
            format!("{:.0}", r_far),
            format!("{:.0}", a_local),
            format!("{:.0}", r_local),
        ]);
        ratios.push((name, r_far / a_far.max(0.001), a_local / r_local.max(0.001)));
    }
    t.emit("tab1_chiplet_accesses");

    println!("paper shape check: ARCAS remote-NUMA accesses << RING; local >= RING");
    for (name, far_ratio, local_ratio) in ratios {
        println!(
            "  {name:<9} RING/ARCAS remote = {far_ratio:>10.0}x   ARCAS/RING local = {local_ratio:.2}x"
        );
    }
}
