//! PJRT round-trip integration: load the AOT JAX/Pallas artifacts,
//! execute through the xla crate's CPU client, and check real numerics
//! against the rust oracle.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use arcas::runtime::{load_manifest, PjrtGrad, PjrtRuntime};
use arcas::workloads::sgd::{GradEngine, RustGrad};

fn artifacts_dir() -> Option<String> {
    if !PjrtRuntime::backend_available() {
        eprintln!("SKIP: built without the `pjrt` feature (no xla backend)");
        return None;
    }
    let dir = PjrtRuntime::default_dir();
    if std::path::Path::new(&format!("{dir}/manifest.txt")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_lists_expected_entries() {
    let Some(dir) = artifacts_dir() else { return };
    let specs = load_manifest(&dir).unwrap();
    let names: Vec<_> = specs.iter().map(|s| s.name.as_str()).collect();
    assert!(names.contains(&"logreg_loss_grad_b64_f64"), "{names:?}");
    assert!(names.contains(&"sgd_step_b128_f1024"));
    assert!(names.contains(&"pdist_n256_k16_d16"));
    for s in &specs {
        assert!(!s.inputs.is_empty());
        assert!(!s.outputs.is_empty());
    }
}

#[test]
fn runtime_compiles_all_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    assert!(rt.len() >= 8, "names={:?}", rt.names());
    assert!(!rt.platform.is_empty());
}

#[test]
fn pjrt_loss_grad_matches_rust_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let (b, f) = (64usize, 64usize);
    let engine = PjrtGrad::new(rt, b, f).unwrap();

    // Deterministic inputs.
    let mut rng = arcas::util::Rng::new(2024);
    let x: Vec<f32> = (0..b * f)
        .map(|_| rng.gen_normal() as f32 / (f as f32).sqrt())
        .collect();
    let y: Vec<f32> = (0..b).map(|_| if rng.gen_bool(0.5) { 1.0 } else { 0.0 }).collect();
    let w: Vec<f32> = (0..f).map(|_| rng.gen_normal() as f32 * 0.1).collect();

    let (loss_p, grad_p) = engine.loss_grad(&x, &y, &w, f);
    let (loss_r, grad_r) = RustGrad.loss_grad(&x, &y, &w, f);

    assert!(
        (loss_p - loss_r).abs() < 1e-4 * loss_r.abs().max(1.0),
        "pjrt loss {loss_p} vs rust {loss_r}"
    );
    assert_eq!(grad_p.len(), grad_r.len());
    for i in 0..f {
        assert!(
            (grad_p[i] - grad_r[i]).abs() < 1e-3,
            "grad[{i}]: pjrt {} vs rust {}",
            grad_p[i],
            grad_r[i]
        );
    }
}

#[test]
fn pjrt_sgd_step_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let exe = rt.get("sgd_step_b64_f64").expect("artifact");
    let (b, f) = (64usize, 64usize);

    let mut rng = arcas::util::Rng::new(7);
    let w_true: Vec<f32> = (0..f).map(|_| rng.gen_normal() as f32).collect();
    let x: Vec<f32> = (0..b * f)
        .map(|_| rng.gen_normal() as f32 / (f as f32).sqrt())
        .collect();
    let y: Vec<f32> = (0..b)
        .map(|i| {
            let dot: f32 = (0..f).map(|j| x[i * f + j] * w_true[j]).sum();
            if dot > 0.0 {
                1.0
            } else {
                0.0
            }
        })
        .collect();

    let mut w = vec![0.0f32; f];
    let lr = [4.0f32];
    let mut losses = Vec::new();
    for _ in 0..5 {
        let outs = exe.run_f32(&[&x, &y, &w, &lr]).unwrap();
        losses.push(outs[0][0]);
        w = outs[1].clone();
    }
    assert!(
        losses[4] < losses[0] * 0.9,
        "losses must decrease: {losses:?}"
    );
}

#[test]
fn pjrt_pdist_matches_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let exe = rt.get("pdist_n256_k16_d16").expect("artifact");
    let (n, k, d) = (256usize, 16usize, 16usize);
    let mut rng = arcas::util::Rng::new(5);
    let p: Vec<f32> = (0..n * d).map(|_| rng.gen_f32()).collect();
    let c: Vec<f32> = (0..k * d).map(|_| rng.gen_f32()).collect();
    let out = exe.run_f32(&[&p, &c]).unwrap();
    assert_eq!(out[0].len(), n * k);
    for i in 0..n {
        for j in 0..k {
            let mut s = 0.0f32;
            for dd in 0..d {
                let diff = p[i * d + dd] - c[j * d + dd];
                s += diff * diff;
            }
            let got = out[0][i * k + j];
            assert!(
                (got - s).abs() < 1e-3 * s.max(1.0),
                "({i},{j}): pjrt {got} vs rust {s}"
            );
        }
    }
}

#[test]
fn wrong_input_shapes_are_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(&dir).unwrap();
    let exe = rt.get("pdist_n256_k16_d16").unwrap();
    let short = vec![0.0f32; 8];
    assert!(exe.run_f32(&[&short, &short]).is_err());
    assert!(exe.run_f32(&[&short]).is_err());
}
