//! Property-based invariant tests (seeded random generation + reproducible
//! failure reporting via `util::proptest`).
//!
//! Coverage: Algorithm 2's placement math, the Chase-Lev deque, the cache
//! model's conservation laws, the scheduler's completion guarantees, the
//! OLAP engine vs its serial oracle, and the config parser roundtrip.

use std::sync::Arc;

use arcas::cachesim::Access;
use arcas::controller::{placement_map_bounded, update_location_bounded};
use arcas::deque::Deque;
use arcas::mem::Placement;
use arcas::policy::{by_name, LocalCachePolicy};
use arcas::sched::run_group;
use arcas::sim::Machine;
use arcas::task::IterTask;
use arcas::topology::Topology;
use arcas::util::proptest::check;
use arcas::util::Rng;

#[test]
fn prop_update_location_bounds_and_determinism() {
    let topo = Topology::milan_2s();
    check(
        "update_location bounds",
        300,
        |rng| {
            let chiplets = 1 + rng.gen_index(topo.num_chiplets());
            let spread = 1 + rng.gen_index(chiplets);
            let group = 1 + rng.gen_index(topo.num_cores());
            let rank = rng.gen_index(group);
            (spread, rank, group, chiplets)
        },
        |&(spread, rank, group, chiplets)| {
            let a = update_location_bounded(&topo, spread, rank, group, chiplets);
            let b = update_location_bounded(&topo, spread, rank, group, chiplets);
            if a != b {
                return Err("non-deterministic".into());
            }
            if let Some(loc) = a {
                if loc.core >= topo.num_cores() {
                    return Err(format!("core {} out of range", loc.core));
                }
                if topo.chiplet_of(loc.core) >= chiplets {
                    return Err(format!(
                        "core {} escapes the {chiplets}-chiplet bound",
                        loc.core
                    ));
                }
                if loc.numa != topo.numa_of_core(loc.core) {
                    return Err("numa mismatch".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_placement_map_is_injective_when_group_fits() {
    let topo = Topology::milan_2s();
    check(
        "placement_map injective",
        200,
        |rng| {
            let chiplets = 1 + rng.gen_index(topo.num_chiplets());
            let spread = 1 + rng.gen_index(chiplets);
            let max_group = chiplets * topo.cores_per_chiplet;
            let group = 1 + rng.gen_index(max_group);
            (spread, group, chiplets)
        },
        |&(spread, group, chiplets)| {
            let map = placement_map_bounded(&topo, spread, group, chiplets);
            if map.len() != group {
                return Err("wrong length".into());
            }
            let uniq: std::collections::BTreeSet<_> = map.iter().collect();
            if uniq.len() != group {
                return Err(format!(
                    "collision: {} cores for {} ranks (spread={spread}, chiplets={chiplets})",
                    uniq.len(),
                    group
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deque_sequential_is_a_stack_plus_fifo_steals() {
    check(
        "deque model",
        100,
        |rng| {
            let n = 1 + rng.gen_index(200);
            (0..n).map(|_| rng.gen_index(3)).collect::<Vec<_>>()
        },
        |ops| {
            // Model with a VecDeque; owner pops back, thief steals front.
            let d = Deque::new();
            let mut model: std::collections::VecDeque<usize> = Default::default();
            let mut next = 0usize;
            for &op in ops {
                match op {
                    0 => {
                        d.push(next);
                        model.push_back(next);
                        next += 1;
                    }
                    1 => {
                        let got = d.pop();
                        let want = model.pop_back();
                        if got != want {
                            return Err(format!("pop: got {got:?} want {want:?}"));
                        }
                    }
                    _ => {
                        let got = d.steal().success();
                        let want = model.pop_front();
                        if got != want {
                            return Err(format!("steal: got {got:?} want {want:?}"));
                        }
                    }
                }
            }
            if d.len() != model.len() {
                return Err("length mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_outcome_conserves_ops() {
    let topo = Topology::milan_2s();
    check(
        "cache conservation",
        200,
        |rng| {
            let size = 64 * (1 + rng.gen_range(1 << 20)); // up to 64 MiB
            let core = rng.gen_index(topo.num_cores());
            let ops = 1 + rng.gen_range(10_000);
            let write = rng.gen_bool(0.3);
            (size, core, ops, write)
        },
        |&(size, core, ops, write)| {
            let m = Machine::new(topo.clone());
            let r = m.alloc("prop", size, Placement::Interleave);
            // Warm chiplet 0 first.
            m.access(0, Access::seq_read(r, size.min(8 << 20)));
            let acc = if write {
                Access::rand_write(r, ops, size)
            } else {
                Access::rand_read(r, ops, size)
            };
            let out = m.access(core, acc);
            let total = out.total_ops();
            if (total - ops as f64).abs() > 1e-6 * ops as f64 {
                return Err(format!("ops {} split to {}", ops, total));
            }
            for (name, v) in [
                ("local", out.local_hits),
                ("near", out.near_hits),
                ("far", out.far_hits),
                ("dram", out.dram_lines),
            ] {
                if v < -1e-9 {
                    return Err(format!("negative {name}: {v}"));
                }
            }
            if out.latency_ns < 0.0 {
                return Err("negative latency".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_residency_never_exceeds_capacity() {
    let topo = Topology::milan_1s().scale_caches(1.0 / 16.0);
    check(
        "residency capacity",
        100,
        |rng| {
            let n_regions = 1 + rng.gen_index(6);
            let accesses: Vec<(usize, u64, bool)> = (0..30)
                .map(|_| {
                    (
                        rng.gen_index(n_regions),
                        64 * (1 + rng.gen_range(1 << 16)),
                        rng.gen_bool(0.5),
                    )
                })
                .collect();
            (n_regions, accesses)
        },
        |(n_regions, accesses)| {
            let m = Machine::new(topo.clone());
            let sizes: Vec<u64> = (0..*n_regions).map(|i| 4 << (18 + i)).collect();
            let ids: Vec<_> = sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| m.alloc(&format!("r{i}"), s, Placement::Interleave))
                .collect();
            for &(ri, bytes, write) in accesses {
                let r = ids[ri];
                let acc = if write {
                    Access::seq_write(r, bytes.min(sizes[ri]))
                } else {
                    Access::seq_read(r, bytes.min(sizes[ri]))
                };
                m.access(0, acc);
                // Invariant: per-chiplet residency within capacity, and
                // per-region residency within the region size.
                for ch in 0..topo.num_chiplets() {
                    let mut used = 0;
                    for (i, &s) in sizes.iter().enumerate() {
                        let res = m.resident(ch, ids[i]);
                        if res > s {
                            return Err(format!("region {i} residency {res} > size {s}"));
                        }
                        used += res;
                    }
                    if used > topo.l3_per_chiplet {
                        return Err(format!(
                            "chiplet {ch} used {used} > capacity {}",
                            topo.l3_per_chiplet
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_executor_completes_all_tasks_under_any_policy() {
    let topo = Topology::milan_2s();
    check(
        "executor completion",
        40,
        |rng| {
            let policy = ["arcas", "ring", "shoal", "local", "distributed", "os_async"]
                [rng.gen_index(6)];
            let tasks = 1 + rng.gen_index(100);
            let iters = 1 + rng.gen_range(8);
            let seed = rng.next_u64();
            (policy, tasks, iters, seed)
        },
        |&(policy, tasks, iters, seed)| {
            let machine = Machine::new(topo.clone());
            let p = by_name(policy, &topo).unwrap();
            let mut rng = Rng::new(seed);
            let costs: Vec<u64> = (0..tasks).map(|_| 100 + rng.gen_range(10_000)).collect();
            let report = run_group(machine, p, tasks, |rank| {
                let c = costs[rank];
                Box::new(IterTask::new(iters, move |ctx, _| ctx.compute_ns(c)))
            });
            let expect = tasks as u64 * iters;
            if report.dispatches != expect {
                return Err(format!(
                    "{policy}: {} dispatches, expected {expect}",
                    report.dispatches
                ));
            }
            if report.makespan_ns == 0 {
                return Err("zero makespan".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_olap_parallel_equals_serial() {
    let db = Arc::new(arcas::workloads::olap::Db::generate(0.001, 31));
    let queries = arcas::workloads::olap::all_queries();
    let topo = Topology::milan_1s();
    check(
        "olap parallel == serial",
        12,
        |rng| {
            let q = rng.gen_index(queries.len());
            let cores = 1 + rng.gen_index(16);
            (q, cores)
        },
        |&(qi, cores)| {
            let q = &queries[qi];
            let (rows, sum) = arcas::workloads::olap::run_query_serial(&db, q);
            let res = arcas::workloads::olap::run_query(
                &topo,
                Box::new(LocalCachePolicy),
                cores,
                db.clone(),
                q,
            );
            if res.rows_out != rows {
                return Err(format!("Q{}: rows {} != {}", q.id, res.rows_out, rows));
            }
            if (res.agg_sum - sum).abs() > sum.abs() * 1e-9 + 1e-6 {
                return Err(format!("Q{}: sum {} != {}", q.id, res.agg_sum, sum));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_config_roundtrip() {
    check(
        "config roundtrip",
        100,
        |rng| {
            let sections = 1 + rng.gen_index(4);
            let mut cfg = arcas::util::config::Config::new();
            for s in 0..sections {
                for k in 0..(1 + rng.gen_index(5)) {
                    cfg.set(
                        &format!("sec{s}"),
                        &format!("key{k}"),
                        &format!("{}", rng.next_u64()),
                    );
                }
            }
            cfg
        },
        |cfg| {
            let text = cfg.to_text();
            let parsed = arcas::util::config::Config::parse(&text)
                .map_err(|e| format!("reparse failed: {e}"))?;
            if &parsed != cfg {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_graph_bfs_parallel_matches_serial_any_graph() {
    let topo = Topology::milan_1s();
    check(
        "bfs parallel == serial",
        10,
        |rng| {
            let scale = 7 + rng.gen_index(4) as u32;
            let ef = 2 + rng.gen_index(8);
            let seed = rng.next_u64();
            let cores = 1 + rng.gen_index(16);
            (scale, ef, seed, cores)
        },
        |&(scale, ef, seed, cores)| {
            let g = Arc::new(arcas::workloads::graph::kronecker::kronecker(scale, ef, seed));
            let src = g.max_degree_vertex();
            let (_, par) = arcas::workloads::graph::run_bfs(
                &topo,
                Box::new(LocalCachePolicy),
                cores,
                g.clone(),
                src,
            );
            let ser = arcas::workloads::graph::algos::bfs_ref(&g, src);
            if par != ser {
                return Err("distance vector mismatch".into());
            }
            Ok(())
        },
    );
}
