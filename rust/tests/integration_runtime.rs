//! Integration: the runtime end-to-end through the public API —
//! executor + policies + cache model + profiler + controller composing.

use arcas::api::{Arcas, ArcasConfig};
use arcas::controller::Approach;
use arcas::mem::Placement;
use arcas::policy::ArcasPolicy;
use arcas::sched::run_group;
use arcas::sim::Machine;
use arcas::task::{IterTask, TaskCtx};
use arcas::topology::Topology;

#[test]
fn end_to_end_api_run_with_adaptive_policy() {
    let mut rt = Arcas::init_with(ArcasConfig {
        topology: Topology::milan_2s(),
        timer_ns: 50_000,
        ..Default::default()
    });
    let region = rt.alloc("shared", 128 << 20, Placement::Interleave);
    let report = rt.all_do_chunked(64, 32, move |ctx, _rank, _| {
        ctx.rand_read(region, 500, 128 << 20);
        ctx.compute_flops(100_000);
    });
    assert_eq!(report.dispatches, 64 * 32);
    assert!(report.makespan_ns > 0);
    assert!(report.counts.total_ops() > 0.0);
    // The adaptive controller must have made decisions.
    assert!(report.spread_rate >= 1);
    rt.finalize();
}

#[test]
fn adaptive_controller_spreads_under_cache_pressure() {
    // Working set >> one chiplet's L3 with heavy remote fills: the
    // controller should move away from maximal compaction.
    let topo = Topology::milan_1s().scale_caches(1.0 / 64.0);
    let machine = Machine::new(topo.clone());
    let region = machine.alloc("big", 64 << 20, Placement::Interleave);
    let policy = ArcasPolicy::new(&topo)
        .with_timer(20_000)
        .with_spread_probe();
    let report = run_group(machine, Box::new(policy), 8, |_| {
        Box::new(IterTask::new(300, move |ctx: &mut TaskCtx<'_>, _| {
            ctx.rand_read(region, 400, 64 << 20);
        }))
    });
    assert!(report.makespan_ns > 0);
}

// Helper extension used above (compact probe start).
trait SpreadProbe {
    fn with_spread_probe(self) -> Self;
}

impl SpreadProbe for ArcasPolicy {
    fn with_spread_probe(self) -> Self {
        self
    }
}

#[test]
fn approaches_bias_final_spread() {
    let topo = Topology::milan_1s();
    let run = |approach: Approach| -> usize {
        let machine = Machine::new(topo.clone());
        let region = machine.alloc("ws", 16 << 20, Placement::Interleave);
        let policy = ArcasPolicy::new(&topo)
            .with_timer(20_000)
            .with_approach(approach);
        run_group(machine, Box::new(policy), 8, |_| {
            Box::new(IterTask::new(200, move |ctx: &mut TaskCtx<'_>, _| {
                ctx.rand_read(region, 300, 16 << 20);
            }))
        })
        .spread_rate
    };
    let loc = run(Approach::LocationCentric);
    let cache = run(Approach::CacheSizeCentric);
    assert!(
        loc <= cache,
        "location-centric ({loc}) must compact at least as much as cache-size-centric ({cache})"
    );
}

#[test]
fn cache_residency_warms_across_runs() {
    let mut rt = Arcas::init_with(ArcasConfig {
        topology: Topology::milan_1s(),
        policy: "local".into(),
        ..Default::default()
    });
    let region = rt.alloc("warm", 4 << 20, Placement::Bind(0));
    let cold = rt.all_do(1, move |ctx, _| {
        ctx.seq_read(region, 4 << 20);
    });
    let warm = rt.all_do(1, move |ctx, _| {
        ctx.seq_read(region, 4 << 20);
    });
    assert!(
        warm.counts.local > cold.counts.local,
        "second run must hit L3 (cold local={}, warm local={})",
        cold.counts.local,
        warm.counts.local
    );
}

#[test]
fn monolithic_topology_neutralizes_chiplet_awareness() {
    // Ablation: on a monolithic LLC machine, ARCAS ≈ Shoal.
    let topo = Topology::monolithic_64();
    let run = |policy: Box<dyn arcas::policy::Policy>| -> u64 {
        let machine = Machine::new(topo.clone());
        let region = machine.alloc("ws", 32 << 20, Placement::Bind(0));
        run_group(machine, policy, 16, |_| {
            Box::new(IterTask::new(50, move |ctx: &mut TaskCtx<'_>, _| {
                ctx.rand_read(region, 200, 32 << 20);
            }))
        })
        .makespan_ns
    };
    let arcas_t = run(Box::new(ArcasPolicy::new(&topo).with_timer(50_000)));
    let shoal_t = run(Box::new(arcas::policy::ShoalPolicy::new()));
    let ratio = arcas_t as f64 / shoal_t as f64;
    assert!(
        (0.7..1.4).contains(&ratio),
        "on monolithic hardware the policies must converge (ratio={ratio})"
    );
}

#[test]
fn config_file_roundtrip_drives_runtime() {
    let text = "
[topology]
preset = milan_1s
[scheduler]
policy = distributed
timer_ns = 1000000
";
    let cfg = arcas::util::config::Config::parse(text).unwrap();
    let ac = ArcasConfig::from_config(&cfg);
    let mut rt = Arcas::init_with(ac);
    let report = rt.all_do(8, |ctx, _| ctx.compute_ns(1000));
    assert_eq!(report.policy, "DistributedCache");
}

#[test]
fn oversubscription_is_supported() {
    // More tasks than cores: everything still completes.
    let mut rt = Arcas::init();
    let report = rt.all_do(500, |ctx, _| ctx.compute_ns(100));
    assert_eq!(report.dispatches, 500);
}

#[test]
fn rpc_call_between_sockets_costs_more_than_local() {
    let mut rt = Arcas::init();
    let t0 = rt.machine().now(0);
    rt.call(0, 1, |ctx| ctx.compute_ns(1));
    let local_cost = rt.machine().now(0) - t0;
    let t1 = rt.machine().now(2);
    // from core 2 to a cross-socket core.
    rt.call(2, 100, |ctx| ctx.compute_ns(1));
    let cross_cost = rt.machine().now(2) - t1;
    assert!(cross_cost > local_cost);
}
