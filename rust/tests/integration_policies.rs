//! Integration: the paper's headline policy comparisons, as assertions.
//!
//! Each test pins one claim from the evaluation narrative on a scaled
//! configuration.

use std::sync::Arc;

use arcas::policy::{
    ArcasPolicy, DistributedCachePolicy, LocalCachePolicy, OsAsyncPolicy, RingPolicy, ShoalPolicy,
};
use arcas::topology::Topology;
use arcas::workloads::graph::{self, kronecker::kronecker};
use arcas::workloads::oltp::{run_oltp, OltpWorkload};
use arcas::workloads::streamcluster::{generate_points, run_streamcluster, ScConfig};

fn milan2() -> Topology {
    Topology::milan_2s().scale_caches(1.0 / 32.0)
}

#[test]
fn q1_arcas_beats_ring_on_graphs_at_scale() {
    // §5.2: chiplet-aware beats NUMA-aware on graph workloads at high
    // core counts.
    let topo = milan2();
    let g = Arc::new(kronecker(12, 8, 3));
    for (name, run) in [
        ("bfs", graph::run_bfs(&topo, Box::new(RingPolicy::new()), 64, g.clone(), 0).0),
        ("sssp", graph::run_sssp(&topo, Box::new(RingPolicy::new()), 64, g.clone(), 0).0),
    ] {
        let arcas = match name {
            "bfs" => graph::run_bfs(
                &topo,
                Box::new(ArcasPolicy::new(&topo).with_timer(20_000)),
                64,
                g.clone(),
                0,
            )
            .0,
            _ => graph::run_sssp(
                &topo,
                Box::new(ArcasPolicy::new(&topo).with_timer(20_000)),
                64,
                g.clone(),
                0,
            )
            .0,
        };
        assert!(
            arcas.report.makespan_ns < run.report.makespan_ns,
            "{name}: arcas {} vs ring {}",
            arcas.report.makespan_ns,
            run.report.makespan_ns
        );
    }
}

#[test]
fn tab1_shape_arcas_converts_remote_to_local() {
    let topo = milan2();
    let g = Arc::new(kronecker(12, 8, 5));
    let (arcas, _) = graph::run_bfs(
        &topo,
        Box::new(ArcasPolicy::new(&topo).with_timer(20_000)),
        64,
        g.clone(),
        0,
    );
    let (ring, _) = graph::run_bfs(&topo, Box::new(RingPolicy::new()), 64, g, 0);
    // ARCAS's remote-NUMA chiplet accesses far below RING's.
    assert!(
        arcas.report.counts.far < ring.report.counts.far / 2.0,
        "arcas far={} ring far={}",
        arcas.report.counts.far,
        ring.report.counts.far
    );
}

#[test]
fn q2_shoal_pathology_at_16_cores() {
    // §5.3: Shoal fills 2 chiplets at 16 cores; ARCAS uses all 8.
    let topo = Topology::milan_1s().scale_caches(1.0 / 128.0);
    let mut cfg = ScConfig::tiny();
    cfg.n_points = 8_000;
    cfg.batch_size = 4_000;
    cfg.dims = 64;
    cfg.local_iters = 6;
    let pts = Arc::new(generate_points(&cfg));
    let shoal = run_streamcluster(&topo, Box::new(ShoalPolicy::new()), 16, &cfg, pts.clone());
    let arcas = run_streamcluster(
        &topo,
        Box::new(ArcasPolicy::new(&topo).with_timer(20_000)),
        16,
        &cfg,
        pts,
    );
    // Tab 2 @16: Shoal pays far more DRAM traffic.
    assert!(
        shoal.report.counts.dram > arcas.report.counts.dram * 1.5,
        "shoal dram={} arcas dram={}",
        shoal.report.counts.dram,
        arcas.report.counts.dram
    );
    assert!(arcas.report.makespan_ns < shoal.report.makespan_ns);
}

#[test]
fn q4_oltp_cache_policies_are_equivalent() {
    // §5.6 / Fig. 13: the null result.
    let topo = Topology::milan_1s();
    let wl = OltpWorkload::Ycsb {
        records: 50_000,
        read_frac: 0.45,
    };
    let local = run_oltp(&topo, Box::new(LocalCachePolicy), 16, &wl, 3_000, 1);
    let dist = run_oltp(&topo, Box::new(DistributedCachePolicy), 16, &wl, 3_000, 1);
    let ratio = local.commits_per_sec() / dist.commits_per_sec();
    assert!((0.8..1.25).contains(&ratio), "ratio={ratio}");
}

#[test]
fn coroutines_beat_os_threads_on_fine_tasks() {
    // §5.4.2 / Fig. 10-11: std::async overhead.
    let topo = Topology::milan_1s();
    let g = Arc::new(kronecker(10, 8, 9));
    let (coro, _) = graph::run_bfs(
        &topo,
        Box::new(LocalCachePolicy),
        8,
        g.clone(),
        0,
    );
    let (os, _) = graph::run_bfs(&topo, Box::new(OsAsyncPolicy::new()), 8, g, 0);
    assert!(
        os.report.makespan_ns > coro.report.makespan_ns,
        "os={} coro={}",
        os.report.makespan_ns,
        coro.report.makespan_ns
    );
}

#[test]
fn finding4_strict_numa_hurts_on_chiplets() {
    // Intro finding 4: "overly strict NUMA-aware optimizations can harm
    // performance on chiplet-based CPUs". RING (strictly NUMA-confined)
    // vs the chiplet-aware adaptive policy on a working set that wants
    // cross-chiplet spread within a socket.
    // RING is NUMA-aware but chiplet-agnostic: on a single NUMA domain it
    // packs 16 workers onto 2 chiplets and keeps rebalancing them with no
    // chiplet awareness. On a working set that needs the aggregate L3 of
    // all 8 chiplets, that strictness loses to adaptive spreading.
    let topo = Topology::milan_1s().scale_caches(1.0 / 128.0);
    let mut cfg = ScConfig::tiny();
    cfg.n_points = 8_000;
    cfg.batch_size = 4_000;
    cfg.dims = 64;
    cfg.local_iters = 6;
    let pts = Arc::new(generate_points(&cfg));
    let ring = run_streamcluster(&topo, Box::new(RingPolicy::new()), 16, &cfg, pts.clone());
    let arcas = run_streamcluster(
        &topo,
        Box::new(ArcasPolicy::new(&topo).with_timer(20_000)),
        16,
        &cfg,
        pts,
    );
    assert!(
        arcas.report.makespan_ns < ring.report.makespan_ns,
        "arcas={} ring={}",
        arcas.report.makespan_ns,
        ring.report.makespan_ns
    );
}

#[test]
fn results_are_deterministic_across_runs() {
    // The whole stack is seeded: identical runs give identical reports.
    let topo = milan2();
    let g = Arc::new(kronecker(11, 8, 13));
    let run = || {
        graph::run_bfs(
            &topo,
            Box::new(ArcasPolicy::new(&topo).with_timer(20_000)),
            32,
            g.clone(),
            0,
        )
        .0
        .report
    };
    let a = run();
    let b = run();
    assert_eq!(a.makespan_ns, b.makespan_ns);
    assert_eq!(a.dispatches, b.dispatches);
    assert_eq!(a.steals, b.steals);
    assert_eq!(a.counts.local, b.counts.local);
}
