//! Scenario × backend conformance suite.
//!
//! Every scenario in the registry runs a small instance on **both**
//! executor backends with verification on:
//!
//! - **Sim** stays golden: run-to-run deterministic, and selecting
//!   `ExecBackend::Sim` explicitly produces the exact report the default
//!   driver path produces (the backend seam is byte-for-byte neutral).
//! - **Host** must pass each scenario's `verify()` hook — the real
//!   algorithm, computed by coroutines stepped on real worker threads
//!   with nondeterministic interleaving, still matches the serial
//!   reference.
//!
//! The suite is self-sealing: `suite_covers_entire_registry` fails when
//! a newly registered scenario is missing from `COVERED`, so adding a
//! workload forces adding its conformance tests.

use arcas::engine::{self, Driver, ExecBackend, ScenarioParams, ScenarioRun};
use arcas::policy::by_name;
use arcas::sched::RunReport;
use arcas::topology::Topology;

/// Small instances, same knobs the engine golden tests use: ~1k-vertex
/// graphs, 4 intensity units, fast enough to run every registry
/// scenario × both backends on every push (the `COVERED` check below
/// keeps the suite in lockstep with the registry as it grows).
fn small_params() -> ScenarioParams {
    ScenarioParams {
        scale: 0.002,
        seed: 11,
        iters: Some(4),
        variant: None,
        trace: None,
    }
}

fn topo() -> Topology {
    Topology::milan_1s()
}

/// The deterministic fields of a report (everything except wall time).
fn key(r: &RunReport) -> (u64, u64, u64, u64, u64, String, String) {
    (
        r.makespan_ns,
        r.dispatches,
        r.steals,
        r.migrations,
        r.barrier_epochs,
        format!("{:?}", r.counts),
        format!("{:.3}", r.dram_bytes),
    )
}

fn run_on(name: &str, backend: Option<ExecBackend>) -> ScenarioRun {
    let spec = engine::by_name(name).unwrap_or_else(|| panic!("{name} not in registry"));
    let mut s = spec.build(&small_params());
    let mut driver = Driver::new(&topo(), by_name("local", &topo()).unwrap(), 8).with_verify(true);
    if let Some(b) = backend {
        driver = driver.with_backend(b);
    }
    driver.run(s.as_mut())
}

/// One scenario's conformance check across both backends.
fn conformance(name: &str) {
    // Sim, selected explicitly, twice: deterministic.
    let sim_a = run_on(name, Some(ExecBackend::Sim));
    let sim_b = run_on(name, Some(ExecBackend::Sim));
    assert_eq!(
        key(&sim_a.report),
        key(&sim_b.report),
        "{name}: sim backend must be run-to-run deterministic"
    );
    // Default driver path (no backend selected) is the same golden report.
    let default_run = run_on(name, None);
    assert_eq!(
        key(&sim_a.report),
        key(&default_run.report),
        "{name}: the backend seam changed the default sim report"
    );
    // Host: with_verify(true) already asserted the scenario's verify()
    // hook against the serial reference; check the report is sane.
    let host = run_on(name, Some(ExecBackend::Host));
    assert!(host.report.dispatches > 0, "{name}: host ran nothing");
    assert!(
        host.report.makespan_ns > 0,
        "{name}: host charged no virtual time"
    );
    assert!(host.report.wall_ns > 0, "{name}: host wall clock missing");
    assert!(host.metrics.items >= 0.0, "{name}: bad host metrics");
}

macro_rules! conformance_tests {
    ($($test:ident => $name:expr;)*) => {
        /// Scenario names this suite covers — compared against the
        /// registry below, so forgetting to add a new scenario here is a
        /// test failure, not silent under-coverage.
        const COVERED: &[&str] = &[$($name),*];

        $(
            #[test]
            fn $test() {
                conformance($name);
            }
        )*
    };
}

conformance_tests! {
    conformance_bfs => "bfs";
    conformance_pagerank => "pagerank";
    conformance_cc => "cc";
    conformance_sssp => "sssp";
    conformance_gups => "gups";
    conformance_streamcluster => "streamcluster";
    conformance_sgd => "sgd";
    conformance_sgd_loss => "sgd-loss";
    conformance_tpch => "tpch";
    conformance_ycsb => "ycsb";
    conformance_tpcc => "tpcc";
    conformance_mixed_oltp_olap => "mixed-oltp-olap";
    conformance_serve_kv => "serve-kv";
    conformance_serve_mixed => "serve-mixed";
}

#[test]
fn suite_covers_entire_registry() {
    for spec in engine::registry() {
        assert!(
            COVERED.contains(&spec.name),
            "scenario {:?} is registered but missing from the backend conformance suite — \
             add it to conformance_tests! in rust/tests/backend_conformance.rs",
            spec.name
        );
    }
    for name in COVERED {
        assert!(
            engine::by_name(name).is_some(),
            "conformance suite covers {name:?}, which is no longer registered"
        );
    }
    assert_eq!(
        COVERED.len(),
        engine::registry().len(),
        "coverage list and registry disagree"
    );
}

/// Serving scenarios must carry a per-request latency report on BOTH
/// backends (host interleavings vary, but every request is served and
/// sampled), and the sim-backend latency numbers are deterministic.
#[test]
fn serving_scenarios_report_latency_on_both_backends() {
    for name in ["serve-kv", "serve-mixed"] {
        let sim_a = run_on(name, Some(ExecBackend::Sim));
        let sim_b = run_on(name, Some(ExecBackend::Sim));
        assert_eq!(
            sim_a.report.request_latency, sim_b.report.request_latency,
            "{name}: sim latency report must be deterministic"
        );
        for backend in ExecBackend::ALL {
            let run = run_on(name, Some(backend));
            let l = run
                .report
                .request_latency
                .unwrap_or_else(|| panic!("{name}/{backend}: no latency report"));
            assert_eq!(l.count, 4, "{name}/{backend}: 4 requests must be sampled");
            assert!(
                l.p50_ns <= l.p95_ns && l.p95_ns <= l.p99_ns && l.p99_ns <= l.max_ns,
                "{name}/{backend}: quantiles out of order"
            );
            assert!(l.mean_service_ns > 0.0, "{name}/{backend}: no service time");
        }
    }
    // Batch scenarios must NOT grow a latency report.
    let batch = run_on("gups", Some(ExecBackend::Sim));
    assert_eq!(batch.report.request_latency, None);
}

/// The acceptance-criteria invocation: `arcas run --scenario bfs
/// --policy arcas --cores 8 --backend host --verify` (library-level).
#[test]
fn bfs_under_arcas_policy_verifies_on_host() {
    let spec = engine::by_name("bfs").unwrap();
    let mut s = spec.build(&small_params());
    let run = Driver::new(&topo(), by_name("arcas", &topo()).unwrap(), 8)
        .with_backend(ExecBackend::Host)
        .with_verify(true)
        .run(s.as_mut());
    assert!(run.report.dispatches > 0);
    assert!(run.metrics.get("teps").unwrap() > 0.0);
}

/// Warm-cache repetition (`--repeat`) composes with both backends.
#[test]
fn repeat_runs_compose_with_both_backends() {
    for backend in ExecBackend::ALL {
        let spec = engine::by_name("gups").unwrap();
        let runs = engine::run_repeated(
            &topo(),
            2,
            4,
            backend,
            true,
            None,
            || by_name("local", &topo()).unwrap(),
            || spec.build(&small_params()),
        );
        assert_eq!(runs.len(), 2);
        for run in &runs {
            assert!(run.report.makespan_ns > 0, "{backend}: empty repetition");
        }
        // Same machine carried across reps: the second run starts warm.
        assert!(
            runs[1].machine.max_time() >= runs[0].report.makespan_ns,
            "{backend}: machine was not reused"
        );
    }
}
