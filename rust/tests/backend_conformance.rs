//! Scenario × backend conformance suite.
//!
//! Every scenario in the registry runs a small instance on **both**
//! executor backends with verification on:
//!
//! - **Sim** stays golden: run-to-run deterministic, and selecting
//!   `ExecBackend::Sim` explicitly produces the exact report the default
//!   driver path produces (the backend seam is byte-for-byte neutral).
//! - **Host** must pass each scenario's `verify()` hook — the real
//!   algorithm, computed by coroutines stepped on real worker threads
//!   with nondeterministic interleaving, still matches the serial
//!   reference.
//!
//! The suite is self-sealing: `suite_covers_entire_registry` fails when
//! a newly registered scenario is missing from `COVERED`, so adding a
//! workload forces adding its conformance tests.

use arcas::engine::{self, Driver, ExecBackend, ScenarioParams, ScenarioRun};
use arcas::policy::by_name;
use arcas::sched::RunReport;
use arcas::topology::Topology;

/// Small instances, same knobs the engine golden tests use: ~1k-vertex
/// graphs, 4 intensity units, fast enough to run every registry
/// scenario × both backends on every push (the `COVERED` check below
/// keeps the suite in lockstep with the registry as it grows).
fn small_params() -> ScenarioParams {
    ScenarioParams {
        scale: 0.002,
        seed: 11,
        iters: Some(4),
        ..Default::default()
    }
}

fn topo() -> Topology {
    Topology::milan_1s()
}

/// The deterministic fields of a report (everything except wall time).
fn key(r: &RunReport) -> (u64, u64, u64, u64, u64, String, String) {
    (
        r.makespan_ns,
        r.dispatches,
        r.steals,
        r.migrations,
        r.barrier_epochs,
        format!("{:?}", r.counts),
        format!("{:.3}", r.dram_bytes),
    )
}

fn run_on(name: &str, backend: Option<ExecBackend>) -> ScenarioRun {
    let spec = engine::by_name(name).unwrap_or_else(|| panic!("{name} not in registry"));
    let mut s = spec.build(&small_params());
    let mut driver = Driver::new(&topo(), by_name("local", &topo()).unwrap(), 8).with_verify(true);
    if let Some(b) = backend {
        driver = driver.with_backend(b);
    }
    driver.run(s.as_mut())
}

/// One scenario's conformance check across both backends.
fn conformance(name: &str) {
    // Sim, selected explicitly, twice: deterministic.
    let sim_a = run_on(name, Some(ExecBackend::Sim));
    let sim_b = run_on(name, Some(ExecBackend::Sim));
    assert_eq!(
        key(&sim_a.report),
        key(&sim_b.report),
        "{name}: sim backend must be run-to-run deterministic"
    );
    // Default driver path (no backend selected) is the same golden report.
    let default_run = run_on(name, None);
    assert_eq!(
        key(&sim_a.report),
        key(&default_run.report),
        "{name}: the backend seam changed the default sim report"
    );
    // Host: with_verify(true) already asserted the scenario's verify()
    // hook against the serial reference; check the report is sane.
    let host = run_on(name, Some(ExecBackend::Host));
    assert!(host.report.dispatches > 0, "{name}: host ran nothing");
    assert!(
        host.report.makespan_ns > 0,
        "{name}: host charged no virtual time"
    );
    assert!(host.report.wall_ns > 0, "{name}: host wall clock missing");
    assert!(host.metrics.items >= 0.0, "{name}: bad host metrics");
}

macro_rules! conformance_tests {
    ($($test:ident => $name:expr;)*) => {
        /// Scenario names this suite covers — compared against the
        /// registry below, so forgetting to add a new scenario here is a
        /// test failure, not silent under-coverage.
        const COVERED: &[&str] = &[$($name),*];

        $(
            #[test]
            fn $test() {
                conformance($name);
            }
        )*
    };
}

conformance_tests! {
    conformance_bfs => "bfs";
    conformance_bfs_random_roots => "bfs-random-roots";
    conformance_pagerank => "pagerank";
    conformance_cc => "cc";
    conformance_sssp => "sssp";
    conformance_gups => "gups";
    conformance_streamcluster => "streamcluster";
    conformance_sgd => "sgd";
    conformance_sgd_loss => "sgd-loss";
    conformance_tpch => "tpch";
    conformance_ycsb => "ycsb";
    conformance_tpcc => "tpcc";
    conformance_mixed_oltp_olap => "mixed-oltp-olap";
    conformance_phase_shift => "phase-shift";
    conformance_mem_follow => "mem-follow";
    conformance_serve_kv => "serve-kv";
    conformance_serve_mixed => "serve-mixed";
    conformance_serve_cluster => "serve-cluster";
}

/// ISSUE 8: the adaptive loop actually adapts on BOTH backends. On sim
/// the policy timer runs on virtual time; on host the run-level timer is
/// real elapsed time between controller ticks. Either way the
/// phase-shifting scenario must produce live migrations and a non-empty
/// per-window decision log — the host report no longer hardcodes
/// `migrations: 0`.
#[test]
fn phase_shift_migrates_on_both_backends() {
    use arcas::policy::ArcasPolicy;
    let spec = engine::by_name("phase-shift").unwrap();
    let params = ScenarioParams {
        scale: 0.002,
        seed: 11,
        iters: Some(60),
        ..Default::default()
    };

    // Sim: the policy carries its own virtual-time cadence (the sim
    // executor adopts `policy.timer_ns()`).
    let mut s = spec.build(&params);
    let sim = engine::Run::new(&topo())
        .policy(Box::new(ArcasPolicy::new(&topo()).with_timer(20_000)))
        .tasks(16)
        .verify(true)
        .run(s.as_mut());
    assert!(
        sim.report.migrations > 0,
        "sim: the phase shift produced no migrations (decisions: {:?})",
        sim.report.decisions
    );
    assert!(!sim.report.decisions.is_empty(), "sim: no adaptation windows");

    // Host: long phases keep the run alive across many 50 us real-time
    // windows; the `adaptive` policy alias + `Run::timer_ns` is the CLI
    // path (`--policy adaptive --backend host --timer-us 50`).
    let params = ScenarioParams {
        iters: Some(250),
        ..params
    };
    let mut s = spec.build(&params);
    let host = engine::Run::new(&topo())
        .policy(by_name("adaptive", &topo()).unwrap())
        .tasks(16)
        .backend(ExecBackend::Host)
        .timer_ns(50_000)
        .verify(true)
        .run(s.as_mut());
    assert!(
        host.report.migrations > 0,
        "host: the phase shift produced no migrations (decisions: {:?})",
        host.report.decisions
    );
    assert!(
        !host.report.decisions.is_empty(),
        "host: no adaptation windows"
    );
}

/// ISSUE 9: online region re-placement ("data follows tasks") is live
/// and consistently reported on both backends. On sim the virtual-time
/// tick makes the moves deterministic, so the stranded-region scenario
/// must actually re-home its stream away from the last NUMA node; on
/// host the real-time tick makes move *timing* nondeterministic, so the
/// assertion there is the reporting invariant (every applied move has a
/// recorded decision with an in-range destination).
#[test]
fn mem_follow_reports_region_moves_on_both_backends() {
    use arcas::policy::ArcasPolicy;
    let nps4 = Topology::milan_1s_nps4();
    let spec = engine::by_name("mem-follow").unwrap();
    let params = ScenarioParams {
        scale: 0.002, // bytes floor to 2 GiB regardless
        seed: 11,
        iters: Some(60),
        ..Default::default()
    };

    let run_sim = || {
        let mut s = spec.build(&params);
        engine::Run::new(&nps4)
            .policy(Box::new(ArcasPolicy::new(&nps4).with_timer(10_000)))
            .tasks(16)
            .verify(true)
            .run(s.as_mut())
    };
    let sim_a = run_sim();
    assert!(
        sim_a.report.region_moves > 0,
        "sim: the stranded region was never re-homed (decisions: {:?})",
        sim_a.report.region_decisions
    );
    let home = nps4.num_numa() - 1;
    for &(_, _, dest) in &sim_a.report.region_decisions {
        assert!(dest < nps4.num_numa(), "sim: destination out of range");
        assert_ne!(dest, home, "sim: moved back to the stranded home");
    }
    let sim_b = run_sim();
    assert_eq!(
        (sim_a.report.region_moves, &sim_a.report.region_decisions),
        (sim_b.report.region_moves, &sim_b.report.region_decisions),
        "sim: region moves must be run-to-run deterministic"
    );

    let mut s = spec.build(&ScenarioParams {
        iters: Some(250),
        ..params
    });
    let host = engine::Run::new(&nps4)
        .policy(by_name("adaptive", &nps4).unwrap())
        .tasks(16)
        .backend(ExecBackend::Host)
        .timer_ns(50_000)
        .verify(true)
        .run(s.as_mut());
    assert_eq!(
        host.report.region_decisions.len() as u64,
        host.report.region_moves,
        "host: applied moves and recorded decisions disagree"
    );
    for &(_, _, dest) in &host.report.region_decisions {
        assert!(dest < nps4.num_numa(), "host: destination out of range");
    }
}

/// ISSUE 10: the cluster rebalance hook is live on BOTH backends. The
/// routing pre-pass is backend-independent by construction (it runs
/// before any executor is chosen), so the drifting hotspot of
/// `serve-cluster` must make `ArcasPolicy::plan_shard_moves` re-home at
/// least one hot key range, deterministically, with identical routing
/// counters on Sim and Host.
#[test]
fn cluster_rebalances_hot_shards_on_both_backends() {
    use arcas::cluster::{CLUSTER_SLOTS, WINDOW_NS};
    use arcas::policy::ArcasPolicy;
    let spec = engine::by_name("serve-cluster").unwrap();
    // ~6 ms of trace at the registry's 2M rps: crosses several routing
    // window boundaries so the front end gets rebalance opportunities.
    let params = ScenarioParams {
        scale: 0.002,
        seed: 11,
        iters: Some(12_000),
        ..Default::default()
    };
    let run_with = |backend: ExecBackend| {
        let mut s = spec.build(&params);
        let topo2 = topo();
        engine::Run::new(&topo())
            .policy(Box::new(ArcasPolicy::new(&topo()).with_timer(50_000)))
            .tasks(8)
            .backend(backend)
            .verify(true)
            .cluster(4)
            .cluster_policy(move || Box::new(ArcasPolicy::new(&topo2).with_timer(50_000)))
            .run(s.as_mut())
    };

    let sim_a = run_with(ExecBackend::Sim);
    let r = &sim_a.report;
    assert_eq!(r.machines, 4);
    assert!(r.cross_link_hops > 0, "no traffic crossed the link tier");
    assert!(
        r.shard_moves >= 1,
        "the drifting hotspot never triggered a shard re-homing \
         (decisions: {:?})",
        r.shard_decisions
    );
    assert_eq!(
        r.shard_decisions.len() as u64,
        r.shard_moves,
        "applied moves and recorded decisions disagree"
    );
    for &(t_ns, slot, to_shard) in &r.shard_decisions {
        assert_eq!(t_ns % WINDOW_NS, 0, "moves happen at window boundaries");
        assert!(slot < CLUSTER_SLOTS, "slot out of range");
        assert!(to_shard < 4, "destination shard out of range");
    }
    // Every request landed on exactly one shard.
    assert_eq!(r.per_shard.len(), 4);
    let routed: u64 = r.per_shard.iter().map(|s| s.requests).sum();
    assert_eq!(routed, 12_000, "routing dropped or duplicated requests");
    let merged = r.request_latency.as_ref().expect("merged latency report");
    assert_eq!(merged.count + r.request_shed, 12_000);

    // Routing (and therefore every shard's input) is deterministic.
    let sim_b = run_with(ExecBackend::Sim);
    assert_eq!(r.shard_decisions, sim_b.report.shard_decisions);
    assert_eq!(key(r), key(&sim_b.report), "sim cluster run must be deterministic");

    // Host: same pre-pass, so identical routing counters; the shards
    // themselves pass verify() against the serial reference.
    let host = run_with(ExecBackend::Host);
    assert_eq!(host.report.machines, 4);
    assert_eq!(
        (host.report.cross_link_hops, host.report.cross_link_bytes),
        (r.cross_link_hops, r.cross_link_bytes),
        "host: routing must be backend-independent"
    );
    assert_eq!(host.report.shard_decisions, r.shard_decisions);
    assert!(host.report.wall_ns > 0);
}

#[test]
fn suite_covers_entire_registry() {
    for spec in engine::registry() {
        assert!(
            COVERED.contains(&spec.name),
            "scenario {:?} is registered but missing from the backend conformance suite — \
             add it to conformance_tests! in rust/tests/backend_conformance.rs",
            spec.name
        );
    }
    for name in COVERED {
        assert!(
            engine::by_name(name).is_some(),
            "conformance suite covers {name:?}, which is no longer registered"
        );
    }
    assert_eq!(
        COVERED.len(),
        engine::registry().len(),
        "coverage list and registry disagree"
    );
}

/// Serving scenarios must carry a per-request latency report on BOTH
/// backends (host interleavings vary, but every request is served and
/// sampled), and the sim-backend latency numbers are deterministic.
#[test]
fn serving_scenarios_report_latency_on_both_backends() {
    for name in ["serve-kv", "serve-mixed", "serve-cluster"] {
        let sim_a = run_on(name, Some(ExecBackend::Sim));
        let sim_b = run_on(name, Some(ExecBackend::Sim));
        assert_eq!(
            sim_a.report.request_latency, sim_b.report.request_latency,
            "{name}: sim latency report must be deterministic"
        );
        for backend in ExecBackend::ALL {
            let run = run_on(name, Some(backend));
            let l = run
                .report
                .request_latency
                .unwrap_or_else(|| panic!("{name}/{backend}: no latency report"));
            assert_eq!(l.count, 4, "{name}/{backend}: 4 requests must be sampled");
            assert!(
                l.p50_ns <= l.p95_ns && l.p95_ns <= l.p99_ns && l.p99_ns <= l.max_ns,
                "{name}/{backend}: quantiles out of order"
            );
            assert!(l.mean_service_ns > 0.0, "{name}/{backend}: no service time");
        }
    }
    // Batch scenarios must NOT grow a latency report.
    let batch = run_on("gups", Some(ExecBackend::Sim));
    assert_eq!(batch.report.request_latency, None);
}

/// The acceptance-criteria invocation: `arcas run --scenario bfs
/// --policy arcas --cores 8 --backend host --verify` (library-level).
#[test]
fn bfs_under_arcas_policy_verifies_on_host() {
    let spec = engine::by_name("bfs").unwrap();
    let mut s = spec.build(&small_params());
    let run = Driver::new(&topo(), by_name("arcas", &topo()).unwrap(), 8)
        .with_backend(ExecBackend::Host)
        .with_verify(true)
        .run(s.as_mut());
    assert!(run.report.dispatches > 0);
    assert!(run.metrics.get("teps").unwrap() > 0.0);
}

// ---- SLO-aware serving: priority tiers, shedding, overload control ----

use std::sync::Arc;

use arcas::workloads::serve::{PriorityMix, ServeKvScenario, ServeOpts, Trace, TraceConfig};

/// A synthetic serve trace at `rate` with an optional priority mix. The
/// priority column must not perturb the arrival/key stream, so mixed and
/// unmixed traces with the same seed are directly comparable.
fn serve_trace(requests: usize, rate_rps: f64, mix: Option<PriorityMix>) -> Arc<Trace> {
    Arc::new(Trace::synth(&TraceConfig {
        requests,
        rate_rps,
        keyspace: 10_000,
        seed: 17,
        priority_mix: mix,
        ..Default::default()
    }))
}

fn run_serve(
    trace: Arc<Trace>,
    opts: ServeOpts,
    backend: ExecBackend,
) -> (ScenarioRun, u64, [u64; 3]) {
    let mut s = ServeKvScenario::new(10_000, trace).with_opts(opts);
    let run = Driver::new(&topo(), by_name("local", &topo()).unwrap(), 8)
        .with_backend(backend)
        .with_verify(true)
        .run(&mut s);
    let shed_counts = s.shed_counts();
    (run, s.served(), shed_counts)
}

/// Mean service time of a lightly-loaded run — the capacity yardstick
/// the adversarial overload test calibrates itself against, so the
/// bounds track the latency model instead of hard-coding ns.
fn calibrated_service_ns() -> f64 {
    let (run, _, _) = run_serve(
        serve_trace(512, 0.1e6, None),
        ServeOpts::default(),
        ExecBackend::Sim,
    );
    let l = run.report.request_latency.unwrap();
    assert!(l.mean_service_ns > 0.0);
    l.mean_service_ns
}

/// The adversarial overload experiment from the issue: drive serve-kv at
/// ~1.3x calibrated capacity. SLO-aware serving (priority tiers + a
/// queue-wait budget) must keep the Critical tail below a fixed bound
/// and shed only Background; the FCFS baseline on the *identical*
/// arrival stream (same seed, no mix) must violate that bound — asserted
/// here, not eyeballed from a figure.
#[test]
fn slo_aware_overload_beats_fcfs_on_the_critical_tail() {
    let workers = 8.0;
    let service_ns = calibrated_service_ns();
    let capacity_rps = workers / service_ns * 1e9;
    let rate = 1.3 * capacity_rps;
    let requests = 4_000;
    let budget_ns = (10.0 * service_ns) as u64;
    let bound_ns = (20.0 * service_ns) as u64;

    // SLO-aware: 20% critical / 50% background, shed past the budget.
    let mix = PriorityMix {
        critical: 0.2,
        background: 0.5,
    };
    let (slo_run, served, shed_counts) = run_serve(
        serve_trace(requests, rate, Some(mix)),
        ServeOpts {
            slo_shed_ns: Some(budget_ns),
            closed_loop_think_ns: None,
        },
        ExecBackend::Sim,
    );
    assert!(slo_run.report.request_shed > 0, "1.3x capacity must shed");
    assert_eq!(
        served + slo_run.report.request_shed,
        requests as u64,
        "admitted + shed must equal the trace length"
    );
    assert_eq!(
        (shed_counts[0], shed_counts[1]),
        (0, 0),
        "only Background may be shed"
    );
    let crit = slo_run
        .report
        .class_latency
        .iter()
        .find(|(n, _)| *n == "critical")
        .map(|(_, l)| l.clone())
        .expect("critical class report");
    assert!(
        crit.p99_ns < bound_ns,
        "SLO-aware critical p99 {} must stay below {bound_ns} (20x mean service)",
        crit.p99_ns
    );

    // FCFS baseline: identical arrivals (same seed, no priority column),
    // no shedding. The backlog grows without bound, so the overall p99
    // blows through the same budget the SLO run held.
    let (fcfs_run, fcfs_served, _) = run_serve(
        serve_trace(requests, rate, None),
        ServeOpts::default(),
        ExecBackend::Sim,
    );
    assert_eq!(fcfs_served, requests as u64);
    assert_eq!(fcfs_run.report.request_shed, 0);
    let fcfs = fcfs_run.report.request_latency.unwrap();
    assert!(
        fcfs.p99_ns > bound_ns,
        "FCFS p99 {} should violate the bound {bound_ns} at 1.3x capacity",
        fcfs.p99_ns
    );
}

/// Anti-starvation: under a Critical flood, streak promotion keeps
/// serving Background throughout the run instead of parking it behind
/// every Critical request (where its median sojourn would approach the
/// whole makespan).
#[test]
fn background_is_not_starved_under_a_critical_flood() {
    let service_ns = calibrated_service_ns();
    let rate = 1.5 * 8.0 / service_ns * 1e9;
    let mix = PriorityMix {
        critical: 0.9,
        background: 0.1,
    };
    let (run, served, _) = run_serve(
        serve_trace(4_000, rate, Some(mix)),
        ServeOpts::default(),
        ExecBackend::Sim,
    );
    assert_eq!(served, 4_000);
    let bg = run
        .report
        .class_latency
        .iter()
        .find(|(n, _)| *n == "background")
        .map(|(_, l)| l.clone())
        .expect("background class report");
    assert!(
        (bg.p50_ns as f64) < 0.75 * run.report.makespan_ns as f64,
        "background p50 {} vs makespan {} — promotion is not kicking in",
        bg.p50_ns,
        run.report.makespan_ns
    );
}

/// Shed-count conservation holds on BOTH backends: real-thread
/// interleavings change *which* requests are shed, never the invariant
/// that every trace entry is either served or shed exactly once.
#[test]
fn shed_conservation_holds_on_both_backends() {
    let service_ns = calibrated_service_ns();
    let rate = 2.0 * 8.0 / service_ns * 1e9;
    let mix = PriorityMix {
        critical: 0.2,
        background: 0.4,
    };
    let opts = ServeOpts {
        slo_shed_ns: Some((5.0 * service_ns) as u64),
        closed_loop_think_ns: None,
    };
    for backend in ExecBackend::ALL {
        let (run, served, shed_counts) =
            run_serve(serve_trace(2_000, rate, Some(mix)), opts, backend);
        assert_eq!(
            served + run.report.request_shed,
            2_000,
            "{backend}: served {served} + shed {} != trace length",
            run.report.request_shed
        );
        assert_eq!(
            (shed_counts[0], shed_counts[1]),
            (0, 0),
            "{backend}: shed a non-Background request"
        );
    }
}

/// Open- vs closed-loop on both backends: the closed loop never queues
/// (each client issues after the previous response), so its latency
/// cannot diverge even at a rate that buries the open loop.
#[test]
fn closed_loop_never_diverges_on_either_backend() {
    let service_ns = calibrated_service_ns();
    let rate = 2.0 * 8.0 / service_ns * 1e9;
    for backend in ExecBackend::ALL {
        let (open_run, _, _) = run_serve(
            serve_trace(1_000, rate, None),
            ServeOpts::default(),
            backend,
        );
        let open = open_run.report.request_latency.unwrap();
        let (closed_run, served, _) = run_serve(
            serve_trace(1_000, rate, None),
            ServeOpts {
                slo_shed_ns: None,
                closed_loop_think_ns: Some((service_ns * 2.0) as u64),
            },
            backend,
        );
        assert_eq!(served, 1_000, "{backend}: closed loop dropped requests");
        assert_eq!(closed_run.report.request_shed, 0);
        let closed = closed_run.report.request_latency.unwrap();
        assert_eq!(
            closed.mean_queue_ns, 0.0,
            "{backend}: a closed loop has no arrival queue"
        );
        assert!(
            closed.p99_ns < open.p99_ns,
            "{backend}: closed p99 {} must undercut the overloaded open loop {}",
            closed.p99_ns,
            open.p99_ns
        );
    }
}

// ---- Run-until-yield batching equivalence (host) ----

/// `--batch-steps 1` (the old step-per-job pipeline) and the batched
/// default must be outcome-equivalent on every registry scenario: the
/// serial-reference `verify()` hook passes under both, the BSP
/// structure (barrier epochs) is identical, and batch scenarios
/// dispatch the same coroutine step count. Serving scenarios shed
/// interleaving-dependently on host, so they assert conservation
/// (served + shed == trace length, equal across budgets) instead of
/// step-count equality.
#[test]
fn batching_is_outcome_equivalent_on_every_scenario() {
    for spec in engine::registry() {
        let run_with = |batch: usize| {
            let mut s = spec.build(&small_params());
            engine::Run::new(&topo())
                .policy(by_name("local", &topo()).unwrap())
                .tasks(8)
                .backend(ExecBackend::Host)
                .batch_steps(batch)
                .verify(true) // outcome: the serial reference must hold
                .run(s.as_mut())
        };
        let unbatched = run_with(1);
        let batched = run_with(engine::DEFAULT_BATCH_STEPS);
        assert!(unbatched.report.dispatches > 0, "{}: ran nothing", spec.name);
        assert_eq!(
            unbatched.report.barrier_epochs, batched.report.barrier_epochs,
            "{}: batching changed the BSP structure",
            spec.name
        );
        match (
            &unbatched.report.request_latency,
            &batched.report.request_latency,
        ) {
            (Some(a), Some(b)) => assert_eq!(
                a.count + unbatched.report.request_shed,
                b.count + batched.report.request_shed,
                "{}: served+shed conservation differs across batch budgets",
                spec.name
            ),
            (None, None) => assert_eq!(
                unbatched.report.dispatches, batched.report.dispatches,
                "{}: batching changed the coroutine step count",
                spec.name
            ),
            _ => panic!(
                "{}: latency report present under one batch budget only",
                spec.name
            ),
        }
    }
}

/// Warm-cache repetition (`--repeat`) composes with both backends.
#[test]
fn repeat_runs_compose_with_both_backends() {
    for backend in ExecBackend::ALL {
        let spec = engine::by_name("gups").unwrap();
        let runs = engine::run_repeated(
            &topo(),
            2,
            4,
            backend,
            true,
            None,
            || by_name("local", &topo()).unwrap(),
            || spec.build(&small_params()),
        );
        assert_eq!(runs.len(), 2);
        for run in &runs {
            assert!(run.report.makespan_ns > 0, "{backend}: empty repetition");
        }
        // Same machine carried across reps: the second run starts warm.
        assert!(
            runs[1].machine.max_time() >= runs[0].report.makespan_ns,
            "{backend}: machine was not reused"
        );
    }
}
