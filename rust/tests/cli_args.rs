//! CLI-facing tests: `arcas run` argument validation (backend/repeat),
//! the `arcas scenarios` listing, and the acceptance-criteria invocation
//! end-to-end through the real binary.

use arcas::engine::{self, ExecBackend, RunConfig};

fn parse(args: &[&str]) -> Result<RunConfig, String> {
    RunConfig::from_args(args.iter().map(|s| s.to_string()))
}

#[test]
fn run_defaults_to_the_sim_backend_single_run() {
    let c = parse(&[]).unwrap();
    assert_eq!(c.backend, ExecBackend::Sim);
    assert_eq!(c.repeat, 1);
    assert_eq!(c.scenario, "bfs");
    assert_eq!(c.policy, "arcas");
}

#[test]
fn run_accepts_backend_host_and_repeat() {
    let c = parse(&["--backend", "host", "--repeat", "3", "--cores", "8"]).unwrap();
    assert_eq!(c.backend, ExecBackend::Host);
    assert_eq!(c.repeat, 3);
    assert_eq!(c.cores, 8);
}

#[test]
fn run_rejects_unknown_backend() {
    let err = parse(&["--backend", "gpu"]).unwrap_err();
    assert!(err.contains("unknown backend"), "{err}");
    assert!(err.contains("sim|host"), "{err}");
}

#[test]
fn run_rejects_repeat_zero_and_garbage() {
    assert!(parse(&["--repeat", "0"])
        .unwrap_err()
        .contains("--repeat must be >= 1"));
    assert!(parse(&["--repeat", "lots"]).unwrap_err().contains("--repeat"));
    assert!(parse(&["--cores", "0"]).unwrap_err().contains("--cores"));
}

#[test]
fn run_help_documents_the_new_flags() {
    let help = RunConfig::cli()
        .parse_from(["--help".to_string()])
        .unwrap_err();
    for flag in ["--backend", "--repeat", "--scenario", "--verify"] {
        assert!(help.contains(flag), "help is missing {flag}:\n{help}");
    }
}

#[test]
fn scenarios_listing_includes_every_registry_name() {
    let listing = engine::scenarios_table();
    for spec in engine::registry() {
        assert!(
            listing.contains(spec.name),
            "`arcas scenarios` output is missing {:?}:\n{listing}",
            spec.name
        );
        assert!(
            listing.contains(spec.family),
            "`arcas scenarios` output is missing family {:?}",
            spec.family
        );
    }
}

/// The acceptance-criteria invocation against the real binary:
/// `arcas run --scenario bfs --policy arcas --cores 8 --backend host
/// --verify` (at test scale) must exit 0 and report verification.
#[test]
fn arcas_run_bfs_host_verify_end_to_end() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "run",
            "--scenario",
            "bfs",
            "--policy",
            "arcas",
            "--cores",
            "8",
            "--backend",
            "host",
            "--verify",
            "--scale",
            "0.002",
        ])
        .output()
        .expect("spawn arcas binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "arcas run failed:\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(stdout.contains("host backend"), "{stdout}");
    assert!(stdout.contains("verified"), "{stdout}");
}

/// `--repeat` through the real binary: per-repetition lines + warm runs.
#[test]
fn arcas_run_repeat_end_to_end() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "run",
            "--scenario",
            "gups",
            "--policy",
            "local",
            "--cores",
            "4",
            "--repeat",
            "2",
            "--scale",
            "0.002",
            "--iters",
            "1000",
        ])
        .output()
        .expect("spawn arcas binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{stdout}");
    assert!(stdout.contains("rep 0"), "{stdout}");
    assert!(stdout.contains("(warm)"), "{stdout}");
}

/// Unknown backends must be a hard CLI error (exit != 0), not a silent
/// fallback to the simulator.
#[test]
fn arcas_run_unknown_backend_exits_nonzero() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args(["run", "--backend", "gpu"])
        .output()
        .expect("spawn arcas binary");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown backend"), "{stderr}");
}
