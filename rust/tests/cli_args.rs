//! CLI-facing tests: `arcas run` argument validation (backend/repeat),
//! the `arcas scenarios` listing, and the acceptance-criteria invocation
//! end-to-end through the real binary.

use arcas::engine::{self, ExecBackend, RunConfig};

fn parse(args: &[&str]) -> Result<RunConfig, String> {
    RunConfig::from_args(args.iter().map(|s| s.to_string()))
}

#[test]
fn run_defaults_to_the_sim_backend_single_run() {
    let c = parse(&[]).unwrap();
    assert_eq!(c.backend, ExecBackend::Sim);
    assert_eq!(c.repeat, 1);
    assert_eq!(c.scenario, "bfs");
    assert_eq!(c.policy, "arcas");
}

#[test]
fn run_accepts_backend_host_and_repeat() {
    let c = parse(&["--backend", "host", "--repeat", "3", "--cores", "8"]).unwrap();
    assert_eq!(c.backend, ExecBackend::Host);
    assert_eq!(c.repeat, 3);
    assert_eq!(c.cores, 8);
}

#[test]
fn run_rejects_unknown_backend() {
    let err = parse(&["--backend", "gpu"]).unwrap_err();
    assert!(err.contains("unknown backend"), "{err}");
    assert!(err.contains("sim|host"), "{err}");
}

#[test]
fn run_rejects_repeat_zero_and_garbage() {
    assert!(parse(&["--repeat", "0"])
        .unwrap_err()
        .contains("--repeat must be >= 1"));
    assert!(parse(&["--repeat", "lots"]).unwrap_err().contains("--repeat"));
    assert!(parse(&["--cores", "0"]).unwrap_err().contains("--cores"));
}

#[test]
fn run_help_documents_the_new_flags() {
    let help = RunConfig::cli()
        .parse_from(["--help".to_string()])
        .unwrap_err();
    for flag in [
        "--backend",
        "--repeat",
        "--batch-steps",
        "--scenario",
        "--verify",
        "--priority-mix",
        "--slo-p99",
        "--closed-loop",
    ] {
        assert!(help.contains(flag), "help is missing {flag}:\n{help}");
    }
}

#[test]
fn run_parses_batch_steps_and_rejects_zero() {
    let c = parse(&["--batch-steps", "4", "--backend", "host"]).unwrap();
    assert_eq!(c.batch_steps, 4);
    assert!(parse(&["--batch-steps", "0"])
        .unwrap_err()
        .contains("--batch-steps must be >= 1"));
    assert!(parse(&["--batch-steps", "many"])
        .unwrap_err()
        .contains("--batch-steps"));
}

#[test]
fn run_rejects_conflicting_and_malformed_slo_flags() {
    let err = parse(&["--closed-loop", "500", "--slo-p99", "100"]).unwrap_err();
    assert!(
        err.contains("--closed-loop") && err.contains("--slo-p99"),
        "{err}"
    );
    let err = parse(&["--priority-mix", "1.5,0.2"]).unwrap_err();
    assert!(err.contains("--priority-mix"), "{err}");
}

#[test]
fn scenarios_listing_includes_every_registry_name() {
    let listing = engine::scenarios_table();
    for spec in engine::registry() {
        assert!(
            listing.contains(spec.name),
            "`arcas scenarios` output is missing {:?}:\n{listing}",
            spec.name
        );
        assert!(
            listing.contains(spec.family),
            "`arcas scenarios` output is missing family {:?}",
            spec.family
        );
    }
}

/// The acceptance-criteria invocation against the real binary:
/// `arcas run --scenario bfs --policy arcas --cores 8 --backend host
/// --verify` (at test scale) must exit 0 and report verification.
#[test]
fn arcas_run_bfs_host_verify_end_to_end() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "run",
            "--scenario",
            "bfs",
            "--policy",
            "arcas",
            "--cores",
            "8",
            "--backend",
            "host",
            "--verify",
            "--scale",
            "0.002",
        ])
        .output()
        .expect("spawn arcas binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "arcas run failed:\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(stdout.contains("host backend"), "{stdout}");
    assert!(stdout.contains("verified"), "{stdout}");
}

/// `--repeat` through the real binary: per-repetition lines + warm runs.
#[test]
fn arcas_run_repeat_end_to_end() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "run",
            "--scenario",
            "gups",
            "--policy",
            "local",
            "--cores",
            "4",
            "--repeat",
            "2",
            "--scale",
            "0.002",
            "--iters",
            "1000",
        ])
        .output()
        .expect("spawn arcas binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{stdout}");
    assert!(stdout.contains("rep 0"), "{stdout}");
    assert!(stdout.contains("(warm)"), "{stdout}");
}

/// The serving acceptance invocation against the real binary:
/// `arcas run --scenario serve-kv --backend host --verify` must exit 0,
/// report verification and print the p50/p95/p99 request-latency line.
#[test]
fn arcas_run_serve_kv_host_verify_reports_latency() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "run",
            "--scenario",
            "serve-kv",
            "--policy",
            "local",
            "--cores",
            "8",
            "--backend",
            "host",
            "--verify",
            "--scale",
            "0.002",
            "--iters",
            "2000",
        ])
        .output()
        .expect("spawn arcas binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "arcas run serve-kv failed:\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(stdout.contains("host backend"), "{stdout}");
    assert!(stdout.contains("verified"), "{stdout}");
    for needle in ["req sojourn", "p50", "p95", "p99", "mean queue"] {
        assert!(stdout.contains(needle), "missing {needle:?} in:\n{stdout}");
    }
}

/// `--batch-steps 1` (the unbatched step-per-job pipeline) through the
/// real binary: the host run still completes and verifies.
#[test]
fn arcas_run_host_unbatched_pipeline_verifies() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "run",
            "--scenario",
            "gups",
            "--policy",
            "local",
            "--cores",
            "4",
            "--backend",
            "host",
            "--verify",
            "--scale",
            "0.002",
            "--iters",
            "1000",
            "--batch-steps",
            "1",
        ])
        .output()
        .expect("spawn arcas binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "arcas run --batch-steps 1 failed:\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(stdout.contains("host backend"), "{stdout}");
    assert!(stdout.contains("verified"), "{stdout}");
}

/// `--trace` replays a text trace file end-to-end through the binary.
#[test]
fn arcas_run_replays_a_trace_file() {
    let path = std::env::temp_dir().join(format!("arcas_cli_trace_{}.txt", std::process::id()));
    std::fs::write(&path, "# three requests\n0 r 1\n500 u 2\n1000 r 3\n").unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "run",
            "--scenario",
            "serve-kv",
            "--policy",
            "local",
            "--cores",
            "2",
            "--verify",
            "--scale",
            "0.002",
            "--trace",
            path.to_str().unwrap(),
        ])
        .output()
        .expect("spawn arcas binary");
    std::fs::remove_file(&path).ok();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{stdout}");
    assert!(stdout.contains("(3 reqs)"), "{stdout}");
}

/// `arcas bench-check` end-to-end: a seeded p99 regression beyond the
/// tolerance band must exit non-zero against a pinned baseline; within
/// the band it exits 0; an improvement exits 0 with a re-pin warning.
#[test]
fn arcas_bench_check_gates_regressions() {
    let dir = std::env::temp_dir();
    let base_path = dir.join(format!("arcas_gate_base_{}.json", std::process::id()));
    let cur_path = dir.join(format!("arcas_gate_cur_{}.json", std::process::id()));
    let series = |p99: f64| {
        format!(
            "{{\"pinned\": true, \"series\": [{{\"policy\": \"local\", \"backend\": \"sim\", \
             \"p99_ns\": {p99}, \"tol\": 0.10}}]}}"
        )
    };
    std::fs::write(&base_path, series(10_000.0)).unwrap();
    let run = |current: &str| {
        std::fs::write(&cur_path, current).unwrap();
        std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
            .args([
                "bench-check",
                "--kind",
                "serving",
                "--baseline",
                base_path.to_str().unwrap(),
                "--current",
                cur_path.to_str().unwrap(),
            ])
            .output()
            .expect("spawn arcas binary")
    };
    // Seeded regression: +50% p99 against a 10% band -> exit 1.
    let out = run(&series(15_000.0));
    assert!(!out.status.success(), "regression must fail the gate");
    assert!(String::from_utf8_lossy(&out.stderr).contains("REGRESSION"));
    // Within band -> exit 0.
    let out = run(&series(10_400.0));
    assert!(out.status.success(), "in-band result must pass");
    // Improvement -> exit 0 + re-pin nudge.
    let out = run(&series(2_000.0));
    assert!(out.status.success(), "improvement must pass");
    assert!(String::from_utf8_lossy(&out.stdout).contains("re-pin"));
    std::fs::remove_file(&base_path).ok();
    std::fs::remove_file(&cur_path).ok();
}

#[test]
fn run_parses_machines_and_rejects_bad_combinations() {
    let c = parse(&["--machines", "4", "--scenario", "serve-cluster"]).unwrap();
    assert_eq!(c.machines, 4);
    assert_eq!(parse(&[]).unwrap().machines, 1);
    assert!(parse(&["--machines", "0"])
        .unwrap_err()
        .contains("--machines must be >= 1"));
    let err = parse(&["--machines", "4", "--repeat", "2"]).unwrap_err();
    assert!(
        err.contains("--machines") && err.contains("--repeat"),
        "{err}"
    );
}

/// The cluster acceptance invocation against the real binary:
/// `arcas run --scenario serve-cluster --machines 4` must exit 0,
/// verify every shard, and print the fleet block (cross-link traffic +
/// per-shard breakdown).
#[test]
fn arcas_run_serve_cluster_machines_end_to_end() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "run",
            "--scenario",
            "serve-cluster",
            "--policy",
            "arcas",
            "--cores",
            "8",
            "--machines",
            "4",
            "--verify",
            "--scale",
            "0.002",
            "--iters",
            "6000",
        ])
        .output()
        .expect("spawn arcas binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "arcas run --machines 4 failed:\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(stdout.contains("4 shards"), "{stdout}");
    assert!(stdout.contains("cross-link hops"), "{stdout}");
    for shard in ["shard 0", "shard 1", "shard 2", "shard 3"] {
        assert!(stdout.contains(shard), "missing {shard:?} in:\n{stdout}");
    }
    assert!(stdout.contains("verified"), "{stdout}");
}

/// A missing BENCH artifact is the distinct "bench did not run" error
/// (exit 2), not a JSON parse failure — the common CI mistake of gating
/// before the matching bench step must be self-explanatory.
#[test]
fn arcas_bench_check_distinguishes_missing_artifact() {
    let dir = std::env::temp_dir();
    let base_path = dir.join(format!("arcas_missing_base_{}.json", std::process::id()));
    std::fs::write(
        &base_path,
        "{\"pinned\": true, \"speedup_n4_vs_n1\": 2.0, \"tol\": 0.25}",
    )
    .unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "bench-check",
            "--kind",
            "cluster",
            "--baseline",
            base_path.to_str().unwrap(),
            "--current",
            "/nonexistent/BENCH_cluster_scaling.json",
        ])
        .output()
        .expect("spawn arcas binary");
    std::fs::remove_file(&base_path).ok();
    assert_eq!(out.status.code(), Some(2), "usage error, not a regression");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("bench did not run"), "{stderr}");
    assert!(!stderr.contains("not valid JSON"), "{stderr}");
}

/// SLO serving end-to-end: a prioritized overloaded run with a shed
/// budget prints the shed line and per-class tails, and verifies.
#[test]
fn arcas_run_serve_kv_slo_prints_class_tails_and_shed() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args([
            "run",
            "--scenario",
            "serve-kv",
            "--policy",
            "local",
            "--cores",
            "4",
            "--verify",
            "--scale",
            "0.002",
            "--iters",
            "2000",
            "--priority-mix",
            "0.2,0.4",
            "--slo-p99",
            "50",
        ])
        .output()
        .expect("spawn arcas binary");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "arcas run serve-kv SLO failed:\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(stdout.contains("verified"), "{stdout}");
    for needle in ["class critical", "class normal", "class background"] {
        assert!(stdout.contains(needle), "missing {needle:?} in:\n{stdout}");
    }
}

/// A serve-only knob against a batch scenario is a hard CLI error that
/// names the flag and lists what the scenario accepts.
#[test]
fn arcas_run_rejects_slo_flags_on_batch_scenarios() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args(["run", "--scenario", "gups", "--priority-mix", "0.2,0.2"])
        .output()
        .expect("spawn arcas binary");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--priority-mix"), "{stderr}");
    assert!(stderr.contains("gups"), "{stderr}");
}

/// Unknown backends must be a hard CLI error (exit != 0), not a silent
/// fallback to the simulator.
#[test]
fn arcas_run_unknown_backend_exits_nonzero() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_arcas"))
        .args(["run", "--backend", "gpu"])
        .output()
        .expect("spawn arcas binary");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown backend"), "{stderr}");
}
