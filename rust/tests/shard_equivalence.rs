//! Shard-vs-monolith equivalence: the sharded machine accounting
//! (per-chiplet/per-socket shards, `crate::coordinator`) must be
//! **byte-for-byte** the pre-refactor monolithic arrangement when driven
//! single-threaded, and must conserve every charge when driven
//! concurrently.
//!
//! The oracle below *is* the pre-refactor `Machine` layout, rebuilt from
//! the same primitives: one flat `Vec<ChipletL3>`, one machine-global
//! LRU stamp, one clock vector, `Vec<BwTracker>`s for DDR and IF links,
//! and the same `classify()` math over directly indexed residency. The
//! property drives seeded random schedules (allocs, reads/writes of
//! every pattern shape, messages, pure compute, barrier-style clock
//! syncs) through both arrangements and requires exact equality of
//! every outcome field, every core clock, the per-class counter totals,
//! and the DRAM byte totals.

use arcas::cachesim::{classify, Access, ChipletL3, ClassCounts, Outcome, Pattern, LINE};
use arcas::mem::{MemoryManager, Placement, RegionId};
use arcas::memsim::{BwTracker, BW_WINDOW_NS};
use arcas::sim::{Machine, ProbeCache, RegionBookCache};
use arcas::topology::Topology;
use arcas::util::proptest::check;
use arcas::util::Rng;

/// The pre-refactor monolithic machine accounting, kept as a test oracle.
struct Monolith {
    topo: Topology,
    l3s: Vec<ChipletL3>,
    counts: Vec<ClassCounts>,
    /// Machine-global LRU recency stamp (the sharded machine keeps one
    /// per chiplet; eviction order only depends on per-chiplet
    /// monotonicity, which this suite is precisely here to prove).
    stamp: u64,
    ddr: Vec<BwTracker>,
    links: Vec<BwTracker>,
    mm: MemoryManager,
    clocks: Vec<u64>,
}

impl Monolith {
    fn new(topo: Topology) -> Self {
        Self {
            l3s: (0..topo.num_chiplets())
                .map(|_| ChipletL3::new(topo.l3_per_chiplet))
                .collect(),
            counts: vec![ClassCounts::default(); topo.num_chiplets()],
            stamp: 0,
            ddr: (0..topo.sockets)
                .map(|_| BwTracker::new(topo.mem_bw_per_socket(), BW_WINDOW_NS))
                .collect(),
            links: (0..topo.num_chiplets())
                .map(|_| BwTracker::new(topo.if_bw_per_chiplet, BW_WINDOW_NS))
                .collect(),
            mm: MemoryManager::new(),
            clocks: vec![0; topo.num_cores()],
            topo,
        }
    }

    fn alloc(&mut self, label: &str, size: u64, placement: Placement) -> RegionId {
        self.mm.alloc(label, size, placement)
    }

    fn access(&mut self, core: usize, acc: Access) -> Outcome {
        let now = self.clocks[core] as f64;
        let my_chiplet = self.topo.chiplet_of(core);
        let my_numa = self.topo.numa_of_core(core);
        let size = self.mm.size(acc.region);
        let (home, local_frac) = self.mm.dram_home(acc.region, my_numa, self.topo.num_numa());

        // Pre-refactor `CacheSim::access` bumped the global stamp before
        // the zero-ops early return; keep that quirk.
        self.stamp += 1;
        if acc.pattern.ops() == 0 {
            return Outcome::default();
        }

        // Monolithic arrangement: residency by direct Vec indexing.
        let classified = classify(&self.topo, core, acc, size, |ch| {
            self.l3s[ch].resident(acc.region)
        });
        let mut out = classified.out;

        // Fill + coherence + counters, monolithically indexed.
        let unique = acc.pattern.unique_bytes().min(size);
        let fill_bytes = ((unique as f64) * (1.0 - classified.p_local)) as u64;
        self.l3s[my_chiplet].fill(acc.region, fill_bytes, self.stamp, size);
        if acc.write {
            let written_frac = (unique as f64 / size.max(1) as f64).min(1.0);
            for ch in 0..self.l3s.len() {
                if ch != my_chiplet {
                    self.l3s[ch].invalidate_frac(acc.region, written_frac);
                }
            }
        }
        self.counts[my_chiplet].add(&out);

        // Remote-homed DRAM latency correction.
        if local_frac < 1.0 {
            let remote_lines = out.dram_lines * (1.0 - local_frac);
            let extra = self.topo.lat.dram_remote_ns - self.topo.lat.dram_local_ns;
            out.latency_ns += remote_lines * extra / acc.mlp.max(1.0);
        }

        // DDR + IF-link bandwidth stages (slower dominates).
        let bw_ns = if out.dram_bytes > 0.0 {
            let bw_numa = if local_frac >= 1.0 { my_numa } else { home };
            let socket = self.topo.socket_of_numa(bw_numa);
            let ddr = self.ddr[socket].charge(now, out.dram_bytes);
            let link = self.links[my_chiplet].charge(now, out.dram_bytes);
            ddr.max(link)
        } else {
            0.0
        };
        out.latency_ns += bw_ns;
        self.clocks[core] += out.latency_ns.round() as u64;
        out
    }

    /// Mirror of [`Machine::move_region`] on the monolithic layout:
    /// refuse unknown ids and moves to the current home, else rebind,
    /// drop the region's residency in every L3 (chiplet order, exactly
    /// like `Shards::drop_region`) and charge the size-proportional DDR
    /// copy on the destination socket to the mover's clock.
    fn move_region(&mut self, id: RegionId, to: usize, mover: usize) -> bool {
        if self.mm.get(id).is_none() || self.mm.placement(id) == Placement::Bind(to) {
            return false;
        }
        let known = self.mm.rebind(id, to);
        debug_assert!(known, "rebind of unknown region {id:?}");
        let size = self.mm.size(id);
        for l3 in &mut self.l3s {
            l3.invalidate_frac(id, 1.0);
        }
        let now = self.clocks[mover] as f64;
        let socket = self.topo.socket_of_numa(to);
        let copy_ns = self.ddr[socket].charge(now, size as f64);
        self.clocks[mover] += copy_ns.round() as u64;
        true
    }

    fn message(&mut self, from: usize, to: usize, bytes: u64) -> u64 {
        let lat = self.topo.core_to_core_ns(from, to);
        let stream = (bytes.saturating_sub(64)) as f64 / 32.0;
        let ns = (lat + stream).round() as u64;
        self.clocks[from] += ns;
        ns
    }

    fn class_totals(&self) -> ClassCounts {
        let mut t = ClassCounts::default();
        for c in &self.counts {
            t.merge(c);
        }
        t
    }

    fn dram_total_bytes(&self) -> f64 {
        self.ddr.iter().map(|t| t.total_bytes()).sum()
    }
}

/// One schedule step.
#[derive(Clone, Debug)]
enum Op {
    Access {
        core: usize,
        region: usize,
        seq: bool,
        amount: u64,
        write: bool,
        mlp: f64,
    },
    Compute {
        core: usize,
        ns: u64,
    },
    Message {
        from: usize,
        to: usize,
        bytes: u64,
    },
    SyncTo {
        core: usize,
        t: u64,
    },
    /// Online region re-placement mid-schedule (the adaptive tick's
    /// "data follows tasks" action).
    MoveRegion {
        region: usize,
        to: usize,
        mover: usize,
    },
}

#[derive(Clone, Debug)]
struct Schedule {
    topo_idx: usize,
    regions: Vec<(u64, Placement)>,
    ops: Vec<Op>,
}

fn topo_for(idx: usize) -> Topology {
    match idx % 3 {
        // Scaled-down caches force real LRU churn and evictions.
        0 => Topology::milan_2s().scale_caches(1.0 / 64.0),
        1 => Topology::milan_1s().scale_caches(1.0 / 16.0),
        _ => Topology::milan_2s(),
    }
}

fn gen_schedule(rng: &mut Rng) -> Schedule {
    let topo_idx = rng.gen_index(3);
    let topo = topo_for(topo_idx);
    let cores = topo.num_cores();
    let n_regions = 1 + rng.gen_index(4);
    let regions: Vec<(u64, Placement)> = (0..n_regions)
        .map(|_| {
            let size = LINE * (1 + rng.gen_range(1 << 17)); // up to 8 MiB
            let placement = match rng.gen_index(3) {
                0 => Placement::Bind(rng.gen_index(topo.num_numa())),
                1 => Placement::Interleave,
                _ => Placement::Replicated,
            };
            (size, placement)
        })
        .collect();
    let n_ops = 60 + rng.gen_index(100);
    let ops = (0..n_ops)
        .map(|_| match rng.gen_index(10) {
            0 => Op::Compute {
                core: rng.gen_index(cores),
                ns: rng.gen_range(100_000),
            },
            1 => Op::Message {
                from: rng.gen_index(cores),
                to: rng.gen_index(cores),
                bytes: rng.gen_range(1 << 16),
            },
            2 => Op::SyncTo {
                core: rng.gen_index(cores),
                t: rng.gen_range(1 << 20),
            },
            3 => Op::MoveRegion {
                region: rng.gen_index(n_regions),
                to: rng.gen_index(topo.num_numa()),
                mover: rng.gen_index(cores),
            },
            _ => {
                let region = rng.gen_index(n_regions);
                let size = regions[region].0;
                let seq = rng.gen_bool(0.5);
                let amount = if seq {
                    1 + rng.gen_range(size)
                } else {
                    1 + rng.gen_range(20_000)
                };
                Op::Access {
                    core: rng.gen_index(cores),
                    region,
                    seq,
                    amount,
                    write: rng.gen_bool(0.3),
                    mlp: [1.0, 1.5, 2.0, 4.0, 8.0][rng.gen_index(5)],
                }
            }
        })
        .collect();
    Schedule {
        topo_idx,
        regions,
        ops,
    }
}

fn build_access(ids: &[RegionId], sizes: &[u64], op: &Op) -> Option<(usize, Access)> {
    if let Op::Access {
        core,
        region,
        seq,
        amount,
        write,
        mlp,
    } = *op
    {
        let pattern = if seq {
            Pattern::Seq { bytes: amount }
        } else {
            Pattern::Rand {
                ops: amount,
                span: sizes[region],
            }
        };
        Some((
            core,
            Access {
                region: ids[region],
                pattern,
                write,
                mlp,
            },
        ))
    } else {
        None
    }
}

#[test]
fn prop_sharded_accounting_equals_the_monolith() {
    check(
        "sharded == monolith",
        25,
        gen_schedule,
        |schedule| {
            let topo = topo_for(schedule.topo_idx);
            let machine = Machine::new(topo.clone());
            let mut oracle = Monolith::new(topo.clone());

            let mut ids_m = Vec::new();
            let mut ids_o = Vec::new();
            let mut sizes = Vec::new();
            for (i, &(size, placement)) in schedule.regions.iter().enumerate() {
                ids_m.push(machine.alloc(&format!("r{i}"), size, placement));
                ids_o.push(oracle.alloc(&format!("r{i}"), size, placement));
                sizes.push(size);
            }
            if ids_m != ids_o {
                return Err("region id streams diverge".into());
            }

            for (i, op) in schedule.ops.iter().enumerate() {
                match op {
                    Op::Access { .. } => {
                        let (core, acc) = build_access(&ids_m, &sizes, op).unwrap();
                        let a = machine.access(core, acc);
                        let b = oracle.access(core, acc);
                        for (name, x, y) in [
                            ("local", a.local_hits, b.local_hits),
                            ("near", a.near_hits, b.near_hits),
                            ("far", a.far_hits, b.far_hits),
                            ("dram", a.dram_lines, b.dram_lines),
                            ("latency", a.latency_ns, b.latency_ns),
                            ("bytes", a.dram_bytes, b.dram_bytes),
                        ] {
                            // Bit-exact: same float op sequence or bust.
                            if x != y {
                                return Err(format!(
                                    "op {i}: outcome.{name} {x} != {y} (sharded vs monolith)"
                                ));
                            }
                        }
                    }
                    Op::Compute { core, ns } => {
                        machine.compute(*core, *ns);
                        oracle.clocks[*core] += ns;
                    }
                    Op::Message { from, to, bytes } => {
                        let a = machine.message(*from, *to, *bytes);
                        let b = oracle.message(*from, *to, *bytes);
                        if a != b {
                            return Err(format!("op {i}: message cost {a} != {b}"));
                        }
                    }
                    Op::SyncTo { core, t } => {
                        machine.advance_to(*core, *t);
                        oracle.clocks[*core] = oracle.clocks[*core].max(*t);
                    }
                    Op::MoveRegion { region, to, mover } => {
                        let a = machine.move_region(ids_m[*region], *to, *mover);
                        let b = oracle.move_region(ids_o[*region], *to, *mover);
                        if a != b {
                            return Err(format!("op {i}: move_region applied {a} != {b}"));
                        }
                    }
                }
            }

            for core in 0..topo.num_cores() {
                if machine.now(core) != oracle.clocks[core] {
                    return Err(format!(
                        "core {core} clock {} != {}",
                        machine.now(core),
                        oracle.clocks[core]
                    ));
                }
            }
            if machine.max_time() != *oracle.clocks.iter().max().unwrap() {
                return Err("makespan diverges".into());
            }
            let (a, b) = (machine.class_totals(), oracle.class_totals());
            if (a.local, a.near, a.far, a.dram) != (b.local, b.near, b.far, b.dram) {
                return Err(format!("class totals diverge: {a:?} vs {b:?}"));
            }
            if machine.dram_total_bytes() != oracle.dram_total_bytes() {
                return Err(format!(
                    "dram bytes diverge: {} vs {}",
                    machine.dram_total_bytes(),
                    oracle.dram_total_bytes()
                ));
            }
            // Residency state (what future accesses will see) matches too.
            for ch in 0..topo.num_chiplets() {
                for (i, id) in ids_m.iter().enumerate() {
                    if machine.resident(ch, *id) != oracle.l3s[ch].resident(*id) {
                        return Err(format!(
                            "chiplet {ch} region {i} residency {} != {}",
                            machine.resident(ch, *id),
                            oracle.l3s[ch].resident(*id)
                        ));
                    }
                }
            }
            // Region placements after the schedule's moves match too.
            for (i, id) in ids_m.iter().enumerate() {
                if machine.placement_of(*id) != oracle.mm.placement(*id) {
                    return Err(format!(
                        "region {i} placement {:?} != {:?}",
                        machine.placement_of(*id),
                        oracle.mm.placement(*id)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Step-batched residency probes are bit-identical to per-access
/// probes: the same seeded schedules driven through `Machine::access`
/// (fresh probes every access) and `Machine::access_cached` with a
/// [`ProbeCache`] that persists across a random number of consecutive
/// accesses (a simulated coroutine step, 1..=8 accesses long) must
/// produce exactly equal outcomes, clocks, counter totals, DRAM bytes
/// and residency. This pins the ROADMAP follow-up from the sharding PR
/// — snapshot residency once per *step* instead of per access — as a
/// pure performance change.
#[test]
fn prop_step_cached_probes_equal_per_access_probes() {
    check(
        "step-cached == uncached",
        25,
        |rng| {
            let s = gen_schedule(rng);
            // Step lengths: how many consecutive ops share one cache.
            let lens: Vec<usize> = (0..s.ops.len()).map(|_| 1 + rng.gen_index(8)).collect();
            (s, lens)
        },
        |(schedule, step_lens)| {
            let topo = topo_for(schedule.topo_idx);
            let plain = Machine::new(topo.clone());
            let cached = Machine::new(topo.clone());

            let mut ids = Vec::new();
            let mut sizes = Vec::new();
            for (i, &(size, placement)) in schedule.regions.iter().enumerate() {
                let a = plain.alloc(&format!("r{i}"), size, placement);
                let b = cached.alloc(&format!("r{i}"), size, placement);
                if a != b {
                    return Err("region id streams diverge".into());
                }
                ids.push(a);
                sizes.push(size);
            }

            let mut cache = ProbeCache::new();
            let mut left_in_step = 0usize;
            let mut step_core = usize::MAX;
            for (i, op) in schedule.ops.iter().enumerate() {
                match op {
                    Op::Access { .. } => {
                        let (core, acc) = build_access(&ids, &sizes, op).unwrap();
                        // Step boundary: a fresh TaskCtx means a fresh
                        // cache. A real cache belongs to one TaskCtx and
                        // so to one core for the whole step — model that
                        // by also ending the step when the core changes
                        // (a cross-core cache could legitimately observe
                        // the other core's fills late).
                        if left_in_step == 0 || core != step_core {
                            cache.clear();
                            left_in_step = step_lens[i];
                            step_core = core;
                        }
                        left_in_step -= 1;
                        let a = plain.access(core, acc);
                        let b = cached.access_cached(core, acc, &mut cache);
                        for (name, x, y) in [
                            ("local", a.local_hits, b.local_hits),
                            ("near", a.near_hits, b.near_hits),
                            ("far", a.far_hits, b.far_hits),
                            ("dram", a.dram_lines, b.dram_lines),
                            ("latency", a.latency_ns, b.latency_ns),
                            ("bytes", a.dram_bytes, b.dram_bytes),
                        ] {
                            if x != y {
                                return Err(format!(
                                    "op {i}: outcome.{name} {x} != {y} (cached vs uncached)"
                                ));
                            }
                        }
                    }
                    Op::Compute { core, ns } => {
                        plain.compute(*core, *ns);
                        cached.compute(*core, *ns);
                    }
                    Op::Message { from, to, bytes } => {
                        let a = plain.message(*from, *to, *bytes);
                        let b = cached.message(*from, *to, *bytes);
                        if a != b {
                            return Err(format!("op {i}: message cost {a} != {b}"));
                        }
                    }
                    Op::SyncTo { core, t } => {
                        plain.advance_to(*core, *t);
                        cached.advance_to(*core, *t);
                    }
                    Op::MoveRegion { region, to, mover } => {
                        let a = plain.move_region(ids[*region], *to, *mover);
                        let b = cached.move_region(ids[*region], *to, *mover);
                        if a != b {
                            return Err(format!("op {i}: move_region applied {a} != {b}"));
                        }
                        // A move bumps the book generation; the task
                        // layer (access_task) drops its probe cache on
                        // the next refresh. This suite drives the raw
                        // probe-cache path, so model that clear here.
                        cache.clear();
                    }
                }
            }

            for core in 0..topo.num_cores() {
                if plain.now(core) != cached.now(core) {
                    return Err(format!(
                        "core {core} clock {} != {}",
                        plain.now(core),
                        cached.now(core)
                    ));
                }
            }
            let (a, b) = (plain.class_totals(), cached.class_totals());
            if (a.local, a.near, a.far, a.dram) != (b.local, b.near, b.far, b.dram) {
                return Err(format!("class totals diverge: {a:?} vs {b:?}"));
            }
            if plain.dram_total_bytes() != cached.dram_total_bytes() {
                return Err("dram bytes diverge".into());
            }
            for ch in 0..topo.num_chiplets() {
                for (i, id) in ids.iter().enumerate() {
                    if plain.resident(ch, *id) != cached.resident(ch, *id) {
                        return Err(format!(
                            "chiplet {ch} region {i} residency {} != {}",
                            plain.resident(ch, *id),
                            cached.resident(ch, *id)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Run-until-yield batching widens the probe-reuse window: the host
/// backend carries one [`ProbeCache`] across *all* consecutive steps of
/// a rank inside a batch, not just the accesses within one step. Model
/// the batch invariant here — a batch never migrates cores, so the
/// cache is cleared only when the running core changes, never on a step
/// boundary — and require outcomes, clocks, counter totals, DRAM bytes
/// and residency to stay bit-identical to fresh per-access probes.
#[test]
fn prop_batch_carried_probes_equal_per_access_probes() {
    check(
        "batch-carried == uncached",
        25,
        gen_schedule,
        |schedule| {
            let topo = topo_for(schedule.topo_idx);
            let plain = Machine::new(topo.clone());
            let cached = Machine::new(topo.clone());

            let mut ids = Vec::new();
            let mut sizes = Vec::new();
            for (i, &(size, placement)) in schedule.regions.iter().enumerate() {
                let a = plain.alloc(&format!("r{i}"), size, placement);
                let b = cached.alloc(&format!("r{i}"), size, placement);
                if a != b {
                    return Err("region id streams diverge".into());
                }
                ids.push(a);
                sizes.push(size);
            }

            let mut cache = ProbeCache::new();
            let mut batch_core = usize::MAX;
            for (i, op) in schedule.ops.iter().enumerate() {
                match op {
                    Op::Access { .. } => {
                        let (core, acc) = build_access(&ids, &sizes, op).unwrap();
                        // The only boundary is a core change: an
                        // unbounded same-core run shares one cache, the
                        // widest window a host batch can ever hold open.
                        if core != batch_core {
                            cache.clear();
                            batch_core = core;
                        }
                        let a = plain.access(core, acc);
                        let b = cached.access_cached(core, acc, &mut cache);
                        for (name, x, y) in [
                            ("local", a.local_hits, b.local_hits),
                            ("near", a.near_hits, b.near_hits),
                            ("far", a.far_hits, b.far_hits),
                            ("dram", a.dram_lines, b.dram_lines),
                            ("latency", a.latency_ns, b.latency_ns),
                            ("bytes", a.dram_bytes, b.dram_bytes),
                        ] {
                            if x != y {
                                return Err(format!(
                                    "op {i}: outcome.{name} {x} != {y} (batch-carried vs uncached)"
                                ));
                            }
                        }
                    }
                    Op::Compute { core, ns } => {
                        plain.compute(*core, *ns);
                        cached.compute(*core, *ns);
                    }
                    Op::Message { from, to, bytes } => {
                        let a = plain.message(*from, *to, *bytes);
                        let b = cached.message(*from, *to, *bytes);
                        if a != b {
                            return Err(format!("op {i}: message cost {a} != {b}"));
                        }
                    }
                    Op::SyncTo { core, t } => {
                        plain.advance_to(*core, *t);
                        cached.advance_to(*core, *t);
                    }
                    Op::MoveRegion { region, to, mover } => {
                        let a = plain.move_region(ids[*region], *to, *mover);
                        let b = cached.move_region(ids[*region], *to, *mover);
                        if a != b {
                            return Err(format!("op {i}: move_region applied {a} != {b}"));
                        }
                        // A move bumps the book generation; the task
                        // layer (access_task) drops its probe cache on
                        // the next refresh. This suite drives the raw
                        // probe-cache path, so model that clear here.
                        cache.clear();
                    }
                }
            }

            for core in 0..topo.num_cores() {
                if plain.now(core) != cached.now(core) {
                    return Err(format!(
                        "core {core} clock {} != {}",
                        plain.now(core),
                        cached.now(core)
                    ));
                }
            }
            let (a, b) = (plain.class_totals(), cached.class_totals());
            if (a.local, a.near, a.far, a.dram) != (b.local, b.near, b.far, b.dram) {
                return Err(format!("class totals diverge: {a:?} vs {b:?}"));
            }
            if plain.dram_total_bytes() != cached.dram_total_bytes() {
                return Err("dram bytes diverge".into());
            }
            for ch in 0..topo.num_chiplets() {
                for (i, id) in ids.iter().enumerate() {
                    if plain.resident(ch, *id) != cached.resident(ch, *id) {
                        return Err(format!(
                            "chiplet {ch} region {i} residency {} != {}",
                            plain.resident(ch, *id),
                            cached.resident(ch, *id)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The lock-free region-book fast path is bit-identical to the locked
/// path: the same seeded schedules (now including mid-schedule region
/// moves) driven through `Machine::access` (book read lock per access)
/// and `Machine::access_task` with a **persistent** [`RegionBookCache`]
/// + batch-carried [`ProbeCache`] must produce exactly equal outcomes,
/// clocks, counter totals, DRAM bytes, residency and placements. The
/// probe cache is cleared only on a core change (the batch invariant) —
/// after a move/rebind the *generation bump alone* must force the
/// snapshot path to re-read the book and drop stale probes. This pins
/// the tentpole claim: zero region-book locks in steady state, as a
/// pure performance change.
#[test]
fn prop_snapshot_book_equals_locked_book_across_moves() {
    check(
        "snapshot book == locked book",
        25,
        gen_schedule,
        |schedule| {
            let topo = topo_for(schedule.topo_idx);
            let locked = Machine::new(topo.clone());
            let snap = Machine::new(topo.clone());

            let mut ids = Vec::new();
            let mut sizes = Vec::new();
            for (i, &(size, placement)) in schedule.regions.iter().enumerate() {
                let a = locked.alloc(&format!("r{i}"), size, placement);
                let b = snap.alloc(&format!("r{i}"), size, placement);
                if a != b {
                    return Err("region id streams diverge".into());
                }
                ids.push(a);
                sizes.push(size);
            }

            let mut cache = ProbeCache::new();
            let mut book = RegionBookCache::new();
            let mut batch_core = usize::MAX;
            for (i, op) in schedule.ops.iter().enumerate() {
                match op {
                    Op::Access { .. } => {
                        let (core, acc) = build_access(&ids, &sizes, op).unwrap();
                        if core != batch_core {
                            cache.clear();
                            batch_core = core;
                        }
                        let a = locked.access(core, acc);
                        let b = snap.access_task(core, acc, &mut cache, &mut book);
                        for (name, x, y) in [
                            ("local", a.local_hits, b.local_hits),
                            ("near", a.near_hits, b.near_hits),
                            ("far", a.far_hits, b.far_hits),
                            ("dram", a.dram_lines, b.dram_lines),
                            ("latency", a.latency_ns, b.latency_ns),
                            ("bytes", a.dram_bytes, b.dram_bytes),
                        ] {
                            if x != y {
                                return Err(format!(
                                    "op {i}: outcome.{name} {x} != {y} (snapshot vs locked)"
                                ));
                            }
                        }
                    }
                    Op::Compute { core, ns } => {
                        locked.compute(*core, *ns);
                        snap.compute(*core, *ns);
                    }
                    Op::Message { from, to, bytes } => {
                        let a = locked.message(*from, *to, *bytes);
                        let b = snap.message(*from, *to, *bytes);
                        if a != b {
                            return Err(format!("op {i}: message cost {a} != {b}"));
                        }
                    }
                    Op::SyncTo { core, t } => {
                        locked.advance_to(*core, *t);
                        snap.advance_to(*core, *t);
                    }
                    Op::MoveRegion { region, to, mover } => {
                        let a = locked.move_region(ids[*region], *to, *mover);
                        let b = snap.move_region(ids[*region], *to, *mover);
                        if a != b {
                            return Err(format!("op {i}: move_region applied {a} != {b}"));
                        }
                        // Deliberately NO cache.clear() here: the bumped
                        // generation must invalidate the snapshot path's
                        // probes on its own.
                    }
                }
            }

            for core in 0..topo.num_cores() {
                if locked.now(core) != snap.now(core) {
                    return Err(format!(
                        "core {core} clock {} != {}",
                        locked.now(core),
                        snap.now(core)
                    ));
                }
            }
            let (a, b) = (locked.class_totals(), snap.class_totals());
            if (a.local, a.near, a.far, a.dram) != (b.local, b.near, b.far, b.dram) {
                return Err(format!("class totals diverge: {a:?} vs {b:?}"));
            }
            if locked.dram_total_bytes() != snap.dram_total_bytes() {
                return Err("dram bytes diverge".into());
            }
            for ch in 0..topo.num_chiplets() {
                for (i, id) in ids.iter().enumerate() {
                    if locked.resident(ch, *id) != snap.resident(ch, *id) {
                        return Err(format!(
                            "chiplet {ch} region {i} residency {} != {}",
                            locked.resident(ch, *id),
                            snap.resident(ch, *id)
                        ));
                    }
                }
            }
            for (i, id) in ids.iter().enumerate() {
                if locked.placement_of(*id) != snap.placement_of(*id) {
                    return Err(format!("region {i} placement diverges after moves"));
                }
            }
            Ok(())
        },
    );
}

/// Concurrent charging conserves every charge: per-core clocks equal the
/// exact sum of that worker's charges, and counter/DRAM totals equal the
/// sum of all returned outcomes (within float-merge tolerance). This is
/// the property that lets the host backend drop its whole-machine lock.
#[test]
fn concurrent_charging_conserves_totals() {
    use std::sync::Arc;
    let topo = Topology::milan_2s().scale_caches(1.0 / 16.0);
    let n_threads = 8usize;
    let per_thread = 200u64;
    let machine = Arc::new(Machine::new(topo.clone()));
    let shared = machine.alloc("shared", 16 << 20, Placement::Interleave);

    let mut handles = Vec::new();
    for t in 0..n_threads {
        let machine = machine.clone();
        // One worker per chiplet, mirroring worker→shard affinity.
        let core = t * topo.cores_per_chiplet;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xC0FFEE ^ t as u64);
            let mut charged = 0u64;
            let mut ops_sum = 0.0f64;
            let mut bytes_sum = 0.0f64;
            for _ in 0..per_thread {
                let out = if rng.gen_bool(0.3) {
                    machine.access(core, Access::seq_write(shared, 1 + rng.gen_range(1 << 18)))
                } else {
                    machine.access(
                        core,
                        Access::rand_read(shared, 1 + rng.gen_range(4_000), 16 << 20),
                    )
                };
                charged += out.latency_ns.round() as u64;
                ops_sum += out.total_ops();
                bytes_sum += out.dram_bytes;
            }
            (core, charged, ops_sum, bytes_sum)
        }));
    }

    let mut total_ops = 0.0;
    let mut total_bytes = 0.0;
    for h in handles {
        let (core, charged, ops_sum, bytes_sum) = h.join().unwrap();
        // Exact: only this thread ever advanced this core's clock.
        assert_eq!(
            machine.now(core),
            charged,
            "core {core}: clock diverges from the sum of its own charges"
        );
        total_ops += ops_sum;
        total_bytes += bytes_sum;
    }
    let totals = machine.class_totals();
    let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1.0);
    assert!(
        rel(totals.total_ops(), total_ops) < 1e-9,
        "counter totals {} != sum of outcomes {}",
        totals.total_ops(),
        total_ops
    );
    assert!(
        rel(machine.dram_total_bytes(), total_bytes) < 1e-9,
        "dram totals {} != sum of outcomes {}",
        machine.dram_total_bytes(),
        total_bytes
    );
}
