//! Golden tests for the scenario-driver layer.
//!
//! Two invariants pin the refactor:
//! 1. **Determinism** — each ported workload family produces an
//!    identical `RunReport` (makespan, access counters, steals, …) on
//!    repeated runs with the same inputs, so the figures the harness
//!    prints are bit-reproducible.
//! 2. **Wrapper ≡ Driver** — the legacy `run_*` entry points and a
//!    hand-driven `engine::Driver` over the same scenario produce the
//!    same report, so nothing rides outside the engine.
//!
//! Plus: every registry scenario resolves and runs (with verification)
//! on a 2-chiplet toy topology.

use std::sync::Arc;

use arcas::engine::{self, Driver, ScenarioParams};
use arcas::policy::by_name;
use arcas::sched::RunReport;
use arcas::topology::Topology;
use arcas::workloads::graph::{self, kronecker::kronecker, BfsScenario};
use arcas::workloads::olap::{all_queries, run_query, Db, OlapScenario};
use arcas::workloads::oltp::{run_oltp, OltpScenario, OltpWorkload};
use arcas::workloads::sgd::{
    generate_data, run_sgd, DwStrategy, RustGrad, SgdConfig, SgdMode, SgdScenario,
};
use arcas::workloads::streamcluster::{generate_points, run_streamcluster, ScConfig, ScScenario};

fn topo() -> Topology {
    Topology::milan_1s()
}

/// The deterministic fields of a report (everything except wall time).
fn key(r: &RunReport) -> (u64, u64, u64, u64, u64, String, String) {
    (
        r.makespan_ns,
        r.dispatches,
        r.steals,
        r.migrations,
        r.barrier_epochs,
        format!("{:?}", r.counts),
        format!("{:.3}", r.dram_bytes),
    )
}

#[test]
fn graph_wrappers_are_deterministic() {
    let g = Arc::new(kronecker(10, 8, 42));
    let (a, da) = graph::run_bfs(&topo(), by_name("local", &topo()).unwrap(), 8, g.clone(), 0);
    let (b, db) = graph::run_bfs(&topo(), by_name("local", &topo()).unwrap(), 8, g.clone(), 0);
    assert_eq!(key(&a.report), key(&b.report));
    assert_eq!(a.edges_processed, b.edges_processed);
    assert_eq!(da, db);

    let (a, _) = graph::run_sssp(&topo(), by_name("ring", &topo()).unwrap(), 8, g.clone(), 0);
    let (b, _) = graph::run_sssp(&topo(), by_name("ring", &topo()).unwrap(), 8, g.clone(), 0);
    assert_eq!(key(&a.report), key(&b.report));
}

#[test]
fn bfs_wrapper_equals_hand_driven_scenario() {
    let g = Arc::new(kronecker(10, 8, 7));
    let (wrapped, dist_w) =
        graph::run_bfs(&topo(), by_name("local", &topo()).unwrap(), 8, g.clone(), 0);

    let mut s = BfsScenario::new(g.clone(), 0);
    let driven = Driver::new(&topo(), by_name("local", &topo()).unwrap(), 8).run(&mut s);
    assert_eq!(key(&wrapped.report), key(&driven.report));
    assert_eq!(wrapped.edges_processed, s.edges_processed());
    assert_eq!(dist_w, s.distances());
    assert_eq!(driven.metrics.items, s.edges_processed() as f64);
}

#[test]
fn streamcluster_wrapper_equals_hand_driven_scenario() {
    let cfg = ScConfig::tiny();
    let pts = Arc::new(generate_points(&cfg));
    let wrapped = run_streamcluster(
        &topo(),
        by_name("local", &topo()).unwrap(),
        4,
        &cfg,
        pts.clone(),
    );
    let mut s = ScScenario::new(cfg.clone(), pts);
    let driven = Driver::new(&topo(), by_name("local", &topo()).unwrap(), 4).run(&mut s);
    assert_eq!(key(&wrapped.report), key(&driven.report));
    assert_eq!(wrapped.n_centers, s.n_centers());
    assert_eq!(wrapped.cost_trace, s.cost_trace());
}

#[test]
fn sgd_wrapper_equals_hand_driven_scenario_and_is_deterministic() {
    let cfg = SgdConfig::tiny();
    let data = generate_data(&cfg);
    let run1 = run_sgd(
        &topo(),
        by_name("shoal", &topo()).unwrap(),
        4,
        &cfg,
        &data,
        DwStrategy::PerCore,
        SgdMode::Grad,
        Arc::new(RustGrad),
    );
    let run2 = run_sgd(
        &topo(),
        by_name("shoal", &topo()).unwrap(),
        4,
        &cfg,
        &data,
        DwStrategy::PerCore,
        SgdMode::Grad,
        Arc::new(RustGrad),
    );
    assert_eq!(key(&run1.report), key(&run2.report));
    assert_eq!(run1.loss_trace, run2.loss_trace);

    let mut s = SgdScenario::new(
        cfg.clone(),
        &data,
        DwStrategy::PerCore,
        SgdMode::Grad,
        Arc::new(RustGrad),
    );
    let driven = Driver::new(&topo(), by_name("shoal", &topo()).unwrap(), 4).run(&mut s);
    assert_eq!(key(&run1.report), key(&driven.report));
    assert_eq!(run1.loss_trace, s.loss_trace());
    assert_eq!(run1.bytes_processed, s.bytes_processed());
}

#[test]
fn oltp_wrapper_equals_hand_driven_scenario() {
    let wl = OltpWorkload::Ycsb {
        records: 10_000,
        read_frac: 0.45,
    };
    let wrapped = run_oltp(&topo(), by_name("local", &topo()).unwrap(), 4, &wl, 1_000, 3);
    let mut s = OltpScenario::new(wl.clone(), 1_000, 3);
    let driven = Driver::new(&topo(), by_name("local", &topo()).unwrap(), 4).run(&mut s);
    assert_eq!(key(&wrapped.report), key(&driven.report));
    assert_eq!(wrapped.commits, s.commits());
    assert_eq!(wrapped.aborts, s.aborts());
    assert_eq!(
        driven.metrics.get("commits_per_s").unwrap(),
        wrapped.commits_per_sec()
    );
}

#[test]
fn olap_wrapper_equals_hand_driven_scenario() {
    let db = Arc::new(Db::generate(0.002, 99));
    let q6 = &all_queries()[5];
    let wrapped = run_query(&topo(), by_name("local", &topo()).unwrap(), 8, db.clone(), q6);
    let mut s = OlapScenario::new(db.clone(), q6.clone());
    let driven = Driver::new(&topo(), by_name("local", &topo()).unwrap(), 8)
        .with_verify(true)
        .run(&mut s);
    assert_eq!(key(&wrapped.report), key(&driven.report));
    assert_eq!(wrapped.rows_out, s.rows_out());
}

/// Golden pin for `serve-kv` on the Sim backend: the full deterministic
/// report — request-latency aggregate included — is identical across
/// fresh builds and runs, the makespan covers the open-loop arrival
/// horizon, and the quantiles are ordered. (Absolute numbers are not
/// hard-coded: the latency model evolves with the machine calibration;
/// run-to-run byte-identity plus the structural invariants are what
/// "golden" means for every other scenario in this suite too.)
#[test]
fn serve_kv_sim_report_is_golden() {
    let params = ScenarioParams {
        scale: 0.002,
        seed: 11,
        iters: Some(512),
        ..Default::default()
    };
    let run_once = || {
        let mut s = engine::by_name("serve-kv").unwrap().build(&params);
        Driver::new(&topo(), by_name("local", &topo()).unwrap(), 8)
            .with_verify(true)
            .run(s.as_mut())
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(key(&a.report), key(&b.report));
    // The Run builder is a pure re-plumbing of the Driver: same scenario,
    // same report, bit for bit.
    let mut s = engine::by_name("serve-kv").unwrap().build(&params);
    let built = engine::Run::new(&topo())
        .policy(by_name("local", &topo()).unwrap())
        .tasks(8)
        .verify(true)
        .run(s.as_mut());
    assert_eq!(key(&a.report), key(&built.report));
    assert_eq!(a.report.request_latency, built.report.request_latency);
    assert_eq!(a.report.request_latency, b.report.request_latency);
    let l = a.report.request_latency.expect("serve-kv must report latency");
    assert_eq!(l.count, 512);
    assert!(l.p50_ns <= l.p95_ns && l.p95_ns <= l.p99_ns && l.p99_ns <= l.max_ns);
    assert!(l.mean_ns >= l.mean_service_ns);
    assert_eq!(a.metrics.items, 512.0);
    assert!(a.metrics.get("p99_sojourn_ns").unwrap() >= 1.0);
}

/// ISSUE 10 golden pin: `--machines 1` is the single-machine run. A
/// one-shard cluster must not route, delay, merge or otherwise perturb
/// anything — the report matches the plain `Run` path byte for byte
/// (same key, same latency aggregate, same metrics), with only the
/// cluster counters stamped on top.
#[test]
fn cluster_of_one_matches_the_single_machine_serve_kv_run() {
    let params = ScenarioParams {
        scale: 0.002,
        seed: 11,
        iters: Some(512),
        ..Default::default()
    };
    let plain = {
        let mut s = engine::by_name("serve-kv").unwrap().build(&params);
        engine::Run::new(&topo())
            .policy(by_name("local", &topo()).unwrap())
            .tasks(8)
            .verify(true)
            .run(s.as_mut())
    };
    let clustered = {
        let mut s = engine::by_name("serve-kv").unwrap().build(&params);
        engine::Run::new(&topo())
            .policy(by_name("local", &topo()).unwrap())
            .tasks(8)
            .verify(true)
            .cluster(1)
            .run(s.as_mut())
    };
    assert_eq!(key(&plain.report), key(&clustered.report));
    assert_eq!(plain.report.request_latency, clustered.report.request_latency);
    assert_eq!(plain.report.request_shed, clustered.report.request_shed);
    assert_eq!(plain.metrics.items, clustered.metrics.items);
    assert_eq!(plain.metrics.extras, clustered.metrics.extras);
    // The only difference: the cluster counters exist (and say "no
    // cross-machine traffic happened").
    assert_eq!(plain.report.machines, 0);
    assert_eq!(clustered.report.machines, 1);
    assert_eq!(clustered.report.cross_link_hops, 0);
    assert_eq!(clustered.report.cross_link_bytes, 0);
    assert_eq!(clustered.report.shard_moves, 0);
    assert_eq!(clustered.report.per_shard.len(), 1);
    assert_eq!(
        clustered.report.per_shard[0].requests,
        512,
        "the one shard owns the whole trace"
    );
    // The adaptive policy goes through the same front-end seam: a
    // 1-shard cluster under arcas also reproduces the plain arcas run.
    let arcas_plain = {
        let mut s = engine::by_name("serve-kv").unwrap().build(&params);
        engine::Run::new(&topo())
            .policy(by_name("arcas", &topo()).unwrap())
            .tasks(8)
            .verify(true)
            .run(s.as_mut())
    };
    let arcas_clustered = {
        let mut s = engine::by_name("serve-kv").unwrap().build(&params);
        engine::Run::new(&topo())
            .policy(by_name("arcas", &topo()).unwrap())
            .tasks(8)
            .verify(true)
            .cluster(1)
            .run(s.as_mut())
    };
    assert_eq!(key(&arcas_plain.report), key(&arcas_clustered.report));
    assert_eq!(
        arcas_plain.report.request_latency,
        arcas_clustered.report.request_latency
    );
}

#[test]
fn every_registry_scenario_runs_verified_on_a_toy_topology() {
    // 2 chiplets × 8 cores: the smallest machine with a chiplet boundary.
    let mut toy = Topology::milan_1s();
    toy.chiplets_per_numa = 2;
    toy.name = "toy_2c".into();
    assert_eq!(toy.num_chiplets(), 2);

    let params = ScenarioParams {
        scale: 0.002,
        seed: 11,
        iters: Some(4),
        ..Default::default()
    };
    for spec in engine::registry() {
        let mut s = spec.build(&params);
        let run = Driver::new(&toy, by_name("local", &toy).unwrap(), 4)
            .with_verify(true)
            .run(s.as_mut());
        assert!(
            run.report.makespan_ns > 0,
            "{}: empty run on the toy topology",
            spec.name
        );
        assert!(
            run.report.dispatches > 0,
            "{}: nothing dispatched",
            spec.name
        );
        assert!(run.metrics.items >= 0.0, "{}", spec.name);
    }
}

#[test]
fn registry_runs_under_every_policy_on_the_toy_topology() {
    let mut toy = Topology::milan_1s();
    toy.chiplets_per_numa = 2;
    let params = ScenarioParams {
        scale: 0.002,
        seed: 5,
        iters: Some(2),
        ..Default::default()
    };
    for policy in ["arcas", "ring", "shoal", "local", "distributed", "os_async", "slo"] {
        let mut s = engine::by_name("bfs").unwrap().build(&params);
        let run = Driver::new(&toy, by_name(policy, &toy).unwrap(), 8)
            .with_verify(true)
            .run(s.as_mut());
        assert!(run.report.makespan_ns > 0, "bfs under {policy}");
    }
}
