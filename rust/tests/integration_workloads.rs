//! Integration: workloads exercised end-to-end on the dual-socket model —
//! larger configurations than the unit tests, multiple policies per
//! workload, result validation throughout.

use std::sync::Arc;

use arcas::policy::by_name;
use arcas::topology::Topology;
use arcas::workloads::graph::{self, algos, kronecker::kronecker};
use arcas::workloads::olap::{all_queries, run_query, run_query_serial, Db};
use arcas::workloads::oltp::{run_oltp, OltpWorkload};
use arcas::workloads::sgd::{generate_data, run_sgd, DwStrategy, RustGrad, SgdConfig, SgdMode};
use arcas::workloads::streamcluster::{generate_points, run_streamcluster, ScConfig};

fn topo() -> Topology {
    Topology::milan_2s()
}

#[test]
fn graph_suite_correct_under_every_policy() {
    let t = topo();
    let g = Arc::new(kronecker(11, 8, 21));
    let src = g.max_degree_vertex();
    let bfs_ref = algos::bfs_ref(&g, src);
    let sssp_ref = algos::sssp_ref(&g, src);
    let cc_count = algos::component_count(&algos::cc_ref(&g));
    for policy in ["arcas", "ring", "shoal", "local", "distributed", "os_async"] {
        let (_, d) = graph::run_bfs(&t, by_name(policy, &t).unwrap(), 24, g.clone(), src);
        assert_eq!(d, bfs_ref, "bfs under {policy}");
        let (_, d) = graph::run_sssp(&t, by_name(policy, &t).unwrap(), 24, g.clone(), src);
        assert_eq!(d, sssp_ref, "sssp under {policy}");
        let (_, l) = graph::run_cc(&t, by_name(policy, &t).unwrap(), 24, g.clone());
        assert_eq!(algos::component_count(&l), cc_count, "cc under {policy}");
    }
}

#[test]
fn pagerank_mass_conserved_at_any_core_count() {
    let t = topo();
    let g = Arc::new(kronecker(10, 8, 23));
    for cores in [1usize, 7, 32, 100] {
        let (_, pr) = graph::run_pagerank(&t, by_name("arcas", &t).unwrap(), cores, g.clone(), 8);
        let sum: f64 = pr.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "cores={cores} sum={sum}");
    }
}

#[test]
fn gups_throughput_reported() {
    let t = topo();
    let (run, _) = graph::run_gups(&t, by_name("arcas", &t).unwrap(), 32, 1 << 16, 20_000, 3);
    assert!(run.teps() > 0.0);
    assert_eq!(run.edges_processed, 32 * 20_000);
}

#[test]
fn streamcluster_output_quality_independent_of_policy() {
    let t = topo();
    let cfg = ScConfig::tiny();
    let pts = Arc::new(generate_points(&cfg));
    let mut costs = Vec::new();
    for policy in ["arcas", "shoal", "distributed"] {
        let res = run_streamcluster(&t, by_name(policy, &t).unwrap(), 8, &cfg, pts.clone());
        assert!(res.n_centers > 1 && res.n_centers <= cfg.k_max);
        costs.push(res.final_cost);
    }
    // Same deterministic opening decisions => identical clustering cost.
    assert!((costs[0] - costs[1]).abs() < 1e-6 * costs[0]);
    assert!((costs[0] - costs[2]).abs() < 1e-6 * costs[0]);
}

#[test]
fn sgd_all_strategies_learn() {
    let t = topo();
    let cfg = SgdConfig::tiny();
    let data = generate_data(&cfg);
    for strategy in [DwStrategy::PerCore, DwStrategy::PerNode, DwStrategy::PerMachine] {
        let run = run_sgd(
            &t,
            by_name("arcas", &t).unwrap(),
            8,
            &cfg,
            &data,
            strategy,
            SgdMode::Grad,
            Arc::new(RustGrad),
        );
        assert!(
            run.final_loss < run.loss_trace[0],
            "{strategy:?}: {:?}",
            run.loss_trace
        );
    }
}

#[test]
fn olap_full_suite_correct_at_16_cores() {
    let t = topo();
    let db = Arc::new(Db::generate(0.001, 29));
    for q in all_queries() {
        let (rows, sum) = run_query_serial(&db, &q);
        let res = run_query(&t, by_name("arcas", &t).unwrap(), 16, db.clone(), &q);
        assert_eq!(res.rows_out, rows, "Q{}", q.id);
        assert!(
            (res.agg_sum - sum).abs() <= sum.abs() * 1e-9 + 1e-6,
            "Q{}: {} vs {}",
            q.id,
            res.agg_sum,
            sum
        );
    }
}

#[test]
fn oltp_abort_rate_rises_with_contention() {
    let t = topo();
    // Tiny key space (hot keys) => RMW conflicts => aborts.
    let hot = OltpWorkload::Ycsb {
        records: 1024,
        read_frac: 0.0,
    };
    let cold = OltpWorkload::Ycsb {
        records: 1_000_000,
        read_frac: 0.0,
    };
    let hot_run = run_oltp(&t, by_name("local", &t).unwrap(), 16, &hot, 3_000, 7);
    let cold_run = run_oltp(&t, by_name("local", &t).unwrap(), 16, &cold, 3_000, 7);
    // Note: the sim executor serializes steps, so aborts come from
    // version-check conflicts across interleaved chunks; the hot keyspace
    // must not abort *less* than the cold one.
    assert!(hot_run.aborts >= cold_run.aborts);
    assert_eq!(hot_run.commits + hot_run.aborts, 16 * 3_000);
}

#[test]
fn tpcc_mix_commits_and_scales() {
    let t = topo();
    let wl = OltpWorkload::TpcC { warehouses: 8 };
    let c4 = run_oltp(&t, by_name("local", &t).unwrap(), 4, &wl, 2_000, 9);
    let c16 = run_oltp(&t, by_name("local", &t).unwrap(), 16, &wl, 2_000, 9);
    assert!(c16.commits_per_sec() > c4.commits_per_sec());
}

#[test]
fn host_executor_runs_graph_kernels_natively() {
    // The runtime is real: run BFS levels as host-pool jobs.
    let t = Topology::milan_1s();
    let g = Arc::new(kronecker(10, 8, 41));
    let src = g.max_degree_vertex();
    let pool = arcas::sched::HostExecutor::new(4, &t, false);
    let n = g.num_vertices();
    let dist: Arc<Vec<std::sync::atomic::AtomicU32>> =
        Arc::new((0..n).map(|_| std::sync::atomic::AtomicU32::new(u32::MAX)).collect());
    dist[src as usize].store(0, std::sync::atomic::Ordering::Relaxed);
    let changed = Arc::new(std::sync::atomic::AtomicBool::new(true));
    let mut level = 0u32;
    while changed.load(std::sync::atomic::Ordering::Relaxed) && level < 1000 {
        changed.store(false, std::sync::atomic::Ordering::Relaxed);
        let chunk = n.div_ceil(8);
        for w in 0..8 {
            let (g, dist, changed) = (g.clone(), dist.clone(), changed.clone());
            pool.execute(move || {
                let lo = (w * chunk).min(n);
                let hi = ((w + 1) * chunk).min(n);
                for v in lo..hi {
                    if dist[v].load(std::sync::atomic::Ordering::Relaxed) == level {
                        for &u in g.neighbors(v as u32) {
                            if dist[u as usize]
                                .compare_exchange(
                                    u32::MAX,
                                    level + 1,
                                    std::sync::atomic::Ordering::Relaxed,
                                    std::sync::atomic::Ordering::Relaxed,
                                )
                                .is_ok()
                            {
                                changed.store(true, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                    }
                }
            });
        }
        pool.wait_all();
        level += 1;
    }
    let got: Vec<u32> = dist
        .iter()
        .map(|d| d.load(std::sync::atomic::Ordering::Relaxed))
        .collect();
    assert_eq!(got, algos::bfs_ref(&g, src), "host-pool BFS must be exact");
}
