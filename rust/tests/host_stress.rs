//! HostExecutor stress & property tests: the pool invariants the host
//! execution backend leans on.
//!
//! - any number of threads may submit concurrently (the per-worker
//!   inboxes serialize external pushes; the Chase–Lev deques stay
//!   owner-only),
//! - jobs may submit follow-up jobs from inside the pool (nested
//!   `execute` via [`Submitter`]), and `wait_all` drains whole chains,
//! - `wait_all` with zero jobs returns immediately,
//! - under a seeded randomized schedule no job is lost or run twice,
//! - the job slot table is recycled, not append-only (regression for the
//!   one-slot-per-job leak),
//! - injector-era invariants: targeted (`execute_on`) jobs drain ahead
//!   of untargeted injector floods on their worker, injector overflow
//!   falls back to inboxes without losing or duplicating jobs, and
//!   submitting to a fully busy pool performs no wakeups at all
//!   (thundering-herd regression).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use arcas::sched::{current_worker, HostExecutor, Submitter};
use arcas::topology::Topology;
use arcas::util::Rng;

fn pool(workers: usize) -> HostExecutor {
    HostExecutor::new(workers, &Topology::milan_1s(), false)
}

#[test]
fn zero_job_wait_all_returns_immediately() {
    let p = pool(4);
    p.wait_all();
    p.wait_all(); // and is idempotent
    p.execute(|| {});
    p.wait_all();
    p.wait_all();
}

#[test]
fn concurrent_submitters_from_many_threads() {
    const THREADS: usize = 8;
    const JOBS: u64 = 500;
    let p = pool(4);
    let counter = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let sub: Submitter = p.submitter();
            let counter = counter.clone();
            std::thread::spawn(move || {
                for j in 0..JOBS {
                    let c = counter.clone();
                    // Mix round-robin and targeted submissions.
                    if j % 2 == 0 {
                        sub.execute(move || {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                    } else {
                        sub.execute_on(t + j as usize, move || {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    p.wait_all();
    assert_eq!(counter.load(Ordering::Relaxed), THREADS as u64 * JOBS);
}

#[test]
fn nested_execute_from_inside_jobs() {
    // Each job spawns two children down to depth 6: a full binary tree,
    // 2^7 - 1 = 127 executions from one root submission. wait_all must
    // see the whole chain, not just the root.
    let p = pool(4);
    let counter = Arc::new(AtomicU64::new(0));

    fn spawn_tree(sub: Submitter, counter: Arc<AtomicU64>, depth: u32) {
        counter.fetch_add(1, Ordering::Relaxed);
        if depth == 0 {
            return;
        }
        for _ in 0..2 {
            let sub2 = sub.clone();
            let c = counter.clone();
            sub.execute(move || spawn_tree(sub2, c, depth - 1));
        }
    }

    let sub = p.submitter();
    let c = counter.clone();
    let sub2 = sub.clone();
    sub.execute(move || spawn_tree(sub2, c, 6));
    p.wait_all();
    assert_eq!(counter.load(Ordering::Relaxed), (1 << 7) - 1);
}

#[test]
fn randomized_schedule_loses_nothing_and_runs_nothing_twice() {
    // Seeded random mix of round-robin vs targeted submissions, bursty
    // round sizes, random tiny busy-work, random wait_all points. Every
    // job bumps its own cell: afterwards each must be exactly 1.
    let mut rng = Rng::new(0xA5CA5);
    let p = pool(6);
    const TOTAL: usize = 4000;
    let cells: Arc<Vec<AtomicU64>> = Arc::new((0..TOTAL).map(|_| AtomicU64::new(0)).collect());
    let mut submitted = 0usize;
    while submitted < TOTAL {
        let burst = (1 + rng.gen_range(64) as usize).min(TOTAL - submitted);
        for _ in 0..burst {
            let id = submitted;
            submitted += 1;
            let cells = cells.clone();
            let spin = rng.gen_range(200);
            let job = move || {
                // Tiny random busy-work so jobs overlap with submission.
                for _ in 0..spin {
                    std::hint::spin_loop();
                }
                cells[id].fetch_add(1, Ordering::Relaxed);
            };
            if rng.gen_range(2) == 0 {
                p.execute(job);
            } else {
                p.execute_on(rng.gen_range(16) as usize, job);
            }
        }
        if rng.gen_range(4) == 0 {
            p.wait_all();
        }
    }
    p.wait_all();
    for (id, c) in cells.iter().enumerate() {
        assert_eq!(
            c.load(Ordering::Relaxed),
            1,
            "job {id} ran {} times (must be exactly once)",
            c.load(Ordering::Relaxed)
        );
    }
}

#[test]
fn slot_table_stays_bounded_across_rounds() {
    // Regression: `Shared.jobs` used to be append-only, leaking one slot
    // per job ever submitted. 100 reuse_after_wait-style rounds of 32
    // jobs must not grow the table past one round's in-flight peak.
    let p = pool(2);
    let counter = Arc::new(AtomicU64::new(0));
    for _ in 0..100 {
        for _ in 0..32 {
            let c = counter.clone();
            p.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        p.wait_all();
    }
    assert_eq!(counter.load(Ordering::Relaxed), 3200);
    assert!(
        p.slot_capacity() <= 32,
        "slot table leaked: {} slots alive after 3200 jobs in rounds of 32",
        p.slot_capacity()
    );
}

#[test]
fn jobs_always_observe_a_worker_identity() {
    // current_worker() is how the host backend charges machine time to
    // the core actually running a step: Some(w) on-pool, None off-pool.
    let p = pool(3);
    let bad = Arc::new(AtomicUsize::new(0));
    for _ in 0..100 {
        let bad = bad.clone();
        p.execute(move || match current_worker() {
            Some(w) if w < 3 => {}
            _ => {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
    }
    p.wait_all();
    assert_eq!(bad.load(Ordering::Relaxed), 0);
    assert_eq!(current_worker(), None);
}

#[test]
fn steals_rebalance_targeted_floods() {
    // Flood one worker's inbox while the others are idle: thieves must
    // take from the flooded queue (steal counter moves) and everything
    // still runs exactly once.
    let p = pool(8);
    let counter = Arc::new(AtomicU64::new(0));
    for _ in 0..256 {
        let c = counter.clone();
        p.execute_on(0, move || {
            let mut s = 1u64;
            for k in 0..20_000u64 {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            std::hint::black_box(s);
            c.fetch_add(1, Ordering::Relaxed);
        });
    }
    p.wait_all();
    assert_eq!(counter.load(Ordering::Relaxed), 256);
    assert!(
        p.steal_count() > 0,
        "8 idle workers never stole from a flooded victim"
    );
}

/// Park one worker on a gate job that spins until `release` flips.
/// Returns once the gate is actually running, so later submissions are
/// guaranteed to queue up behind it.
fn hold_worker(p: &HostExecutor, worker: usize, release: Arc<AtomicU64>) {
    let running = Arc::new(AtomicU64::new(0));
    let r = running.clone();
    p.execute_on(worker, move || {
        r.store(1, Ordering::Release);
        while release.load(Ordering::Acquire) == 0 {
            std::hint::spin_loop();
        }
    });
    while running.load(Ordering::Acquire) == 0 {
        std::hint::spin_loop();
    }
}

#[test]
fn targeted_submits_drain_ahead_of_injector_floods() {
    // The worker drain order is deque -> own inbox -> injector, so a
    // core-targeted job must never be starved behind an untargeted
    // flood: on a single-worker pool every `execute_on(0, ..)` has to
    // run before any of the 200 injector jobs queued ahead of it in
    // wall-clock submission order.
    let p = pool(1);
    let release = Arc::new(AtomicU64::new(0));
    hold_worker(&p, 0, release.clone());

    let seq = Arc::new(AtomicU64::new(0));
    let injector_first = Arc::new(AtomicU64::new(u64::MAX));
    let targeted_last = Arc::new(AtomicU64::new(0));
    for _ in 0..200 {
        let seq = seq.clone();
        let first = injector_first.clone();
        p.execute(move || {
            let s = seq.fetch_add(1, Ordering::Relaxed);
            first.fetch_min(s, Ordering::Relaxed);
        });
    }
    for _ in 0..8 {
        let seq = seq.clone();
        let last = targeted_last.clone();
        p.execute_on(0, move || {
            let s = seq.fetch_add(1, Ordering::Relaxed);
            last.fetch_max(s, Ordering::Relaxed);
        });
    }
    release.store(1, Ordering::Release);
    p.wait_all();
    assert_eq!(seq.load(Ordering::Relaxed), 208);
    assert!(
        targeted_last.load(Ordering::Relaxed) < injector_first.load(Ordering::Relaxed),
        "a targeted job ran after an injector job (targeted_last={} injector_first={})",
        targeted_last.load(Ordering::Relaxed),
        injector_first.load(Ordering::Relaxed)
    );
}

#[test]
fn injector_overflow_falls_back_without_losing_jobs() {
    // 3000 untargeted submissions against a blocked single worker
    // overflow the bounded injector ring (capacity 1024); the excess
    // must spill to the round-robin inbox path, and afterwards every
    // job ran exactly once.
    const TOTAL: usize = 3000;
    let p = pool(1);
    let release = Arc::new(AtomicU64::new(0));
    hold_worker(&p, 0, release.clone());

    let cells: Arc<Vec<AtomicU64>> = Arc::new((0..TOTAL).map(|_| AtomicU64::new(0)).collect());
    for id in 0..TOTAL {
        let cells = cells.clone();
        p.execute(move || {
            cells[id].fetch_add(1, Ordering::Relaxed);
        });
    }
    release.store(1, Ordering::Release);
    p.wait_all();
    for (id, c) in cells.iter().enumerate() {
        assert_eq!(
            c.load(Ordering::Relaxed),
            1,
            "job {id} ran {} times after injector overflow (must be exactly once)",
            c.load(Ordering::Relaxed)
        );
    }
}

#[test]
fn randomized_injector_schedule_with_nested_children() {
    // Like the randomized schedule above, but every root may also spawn
    // injector children from *inside* the pool (the path barrier release
    // uses), interleaved with off-pool targeted submissions. Roots are
    // exactly-once; the child total must match the seeded plan.
    let mut rng = Rng::new(0x17EC7);
    let p = pool(6);
    const ROOTS: usize = 1200;
    let cells: Arc<Vec<AtomicU64>> = Arc::new((0..ROOTS).map(|_| AtomicU64::new(0)).collect());
    let child_runs = Arc::new(AtomicU64::new(0));
    let sub = p.submitter();
    let mut expected_children = 0u64;
    for id in 0..ROOTS {
        let kids = rng.gen_range(4);
        expected_children += kids;
        let cells = cells.clone();
        let child_runs = child_runs.clone();
        let sub2 = sub.clone();
        let job = move || {
            cells[id].fetch_add(1, Ordering::Relaxed);
            for _ in 0..kids {
                let c = child_runs.clone();
                sub2.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        };
        if rng.gen_range(3) == 0 {
            p.execute_on(rng.gen_range(6) as usize, job);
        } else {
            p.execute(job);
        }
        if rng.gen_range(64) == 0 {
            p.wait_all();
        }
    }
    p.wait_all();
    for (id, c) in cells.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "root {id} not exactly-once");
    }
    assert_eq!(child_runs.load(Ordering::Relaxed), expected_children);
}

/// Adaptive migration racing burst submission: a tick that flips every
/// rank's placement slot on every batch boundary, against a BSP group
/// whose barrier releases resubmit the whole group in one burst. The
/// placement swap and the burst's `home_worker` reads race by design;
/// the invariants that must hold anyway: every step runs exactly once,
/// the BSP structure is intact, and migrations were actually applied.
#[test]
fn migration_races_burst_submission() {
    use arcas::engine::{ExecBackend, Run};
    use arcas::policy::Policy;
    use arcas::profiler::WindowSample;
    use arcas::task::BspTask;

    /// Hops the whole group between chiplet 0 and chiplet 1 every tick.
    struct PingPongPolicy {
        flip: bool,
    }

    impl Policy for PingPongPolicy {
        fn name(&self) -> &'static str {
            "ping-pong"
        }
        fn initial_placement(&mut self, topo: &Topology, n: usize) -> Vec<usize> {
            (0..n).map(|r| r % topo.cores_per_chiplet).collect()
        }
        fn on_timer(
            &mut self,
            topo: &Topology,
            _now_ns: u64,
            _sample: &WindowSample,
            group_size: usize,
        ) -> Option<Vec<usize>> {
            self.flip = !self.flip;
            let base = if self.flip { topo.cores_per_chiplet } else { 0 };
            Some(
                (0..group_size)
                    .map(|r| base + r % topo.cores_per_chiplet)
                    .collect(),
            )
        }
    }

    let mut topo = Topology::milan_1s();
    topo.chiplets_per_numa = 2; // 16 cores: a small 2-chiplet pool
    const RANKS: usize = 16;
    const EPOCHS: u64 = 30;
    let hits = Arc::new(AtomicU64::new(0));
    let (report, _) = Run::new(&topo)
        .policy(Box::new(PingPongPolicy { flip: false }))
        .tasks(RANKS)
        .backend(ExecBackend::Host)
        .timer_ns(1) // every batch boundary is past due
        .batch_steps(1) // step-per-job: maximum boundary frequency
        .run_group(|_| {
            let hits = hits.clone();
            Box::new(BspTask::new(EPOCHS, move |ctx, _| {
                hits.fetch_add(1, Ordering::Relaxed);
                ctx.compute_ns(2_000);
            }))
        });
    assert_eq!(
        hits.load(Ordering::Relaxed),
        RANKS as u64 * EPOCHS,
        "a step was lost or duplicated under migration pressure"
    );
    assert_eq!(
        report.barrier_epochs,
        EPOCHS - 1,
        "migration pressure changed the BSP structure"
    );
    assert!(
        report.migrations > 0,
        "the ping-pong policy never actually migrated"
    );
}

/// Online region moves racing in-flight batches: a tick policy that
/// re-homes every hot region on every batch boundary (alternating NUMA
/// nodes) against a BSP group whose ranks hammer that region through the
/// generation-stamped snapshot path. The move's rebind + eviction + gen
/// bump race the batches' `access_task` reads by design; the invariants
/// that must hold anyway: every step runs exactly once, the BSP
/// structure is intact, and moves were actually applied and reported.
#[test]
fn region_moves_race_in_flight_batches() {
    use arcas::engine::{ExecBackend, Run};
    use arcas::mem::Placement;
    use arcas::policy::{Policy, RegionMove};
    use arcas::task::BspTask;
    use std::sync::OnceLock;

    /// Re-homes every region it sees heat for, cycling the destination
    /// NUMA node each tick (moves to the current home refuse cheaply).
    struct RegionPingPongPolicy {
        to: usize,
    }

    impl Policy for RegionPingPongPolicy {
        fn name(&self) -> &'static str {
            "region-ping-pong"
        }
        fn initial_placement(&mut self, topo: &Topology, n: usize) -> Vec<usize> {
            (0..n).map(|r| r % topo.num_cores()).collect()
        }
        fn plan_region_moves(
            &mut self,
            topo: &Topology,
            _now_ns: u64,
            heat: &[arcas::policy::RegionHeat],
            _group_size: usize,
        ) -> Vec<RegionMove> {
            self.to = (self.to + 1) % topo.num_numa();
            heat.iter()
                .map(|h| RegionMove {
                    region: h.region,
                    to_numa: self.to,
                })
                .collect()
        }
    }

    let mut topo = Topology::milan_1s();
    topo.numa_per_socket = 2;
    topo.chiplets_per_numa = 1; // 16 cores, 2 single-chiplet NUMA nodes
    const RANKS: usize = 16;
    const EPOCHS: u64 = 30;
    let hits = Arc::new(AtomicU64::new(0));
    let region = Arc::new(OnceLock::new());
    let (report, _) = Run::new(&topo)
        .policy(Box::new(RegionPingPongPolicy { to: 0 }))
        .tasks(RANKS)
        .backend(ExecBackend::Host)
        .timer_ns(1) // every batch boundary is past due
        .batch_steps(1) // step-per-job: maximum boundary frequency
        .run_group(|_| {
            let hits = hits.clone();
            let region = region.clone();
            Box::new(BspTask::new(EPOCHS, move |ctx, _| {
                let r = *region.get_or_init(|| {
                    ctx.view()
                        .machine()
                        .alloc("hot", 8 << 20, Placement::Bind(0))
                });
                ctx.rand_read(r, 64, 8 << 20);
                hits.fetch_add(1, Ordering::Relaxed);
                ctx.compute_ns(1_000);
            }))
        });
    assert_eq!(
        hits.load(Ordering::Relaxed),
        RANKS as u64 * EPOCHS,
        "a step was lost or duplicated under region-move pressure"
    );
    assert_eq!(
        report.barrier_epochs,
        EPOCHS - 1,
        "region-move pressure changed the BSP structure"
    );
    assert!(
        report.region_moves > 0,
        "the ping-pong policy never actually moved the region"
    );
    assert_eq!(
        report.region_decisions.len() as u64,
        report.region_moves,
        "every applied move must be recorded as a decision"
    );
    for &(_, _, dest) in &report.region_decisions {
        assert!(dest < topo.num_numa(), "move destination out of range");
    }
}

#[test]
fn submits_to_a_busy_pool_perform_no_wakeups() {
    // Thundering-herd regression: the old pool took the park lock and
    // notified on every submission. With lazy wakeups, submitting to a
    // pool whose workers are all running (nobody parked) must not
    // perform a single wakeup.
    let p = pool(4);
    let release = Arc::new(AtomicU64::new(0));
    for w in 0..4 {
        hold_worker(&p, w, release.clone());
    }
    let before = p.wakeup_count();
    let counter = Arc::new(AtomicU64::new(0));
    for _ in 0..1000 {
        let c = counter.clone();
        p.execute(move || {
            c.fetch_add(1, Ordering::Relaxed);
        });
    }
    let during = p.wakeup_count();
    assert_eq!(
        during - before,
        0,
        "flooding a fully busy pool still notified {} times",
        during - before
    );
    release.store(1, Ordering::Release);
    p.wait_all();
    assert_eq!(counter.load(Ordering::Relaxed), 1000);
}
