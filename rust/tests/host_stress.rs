//! HostExecutor stress & property tests: the pool invariants the host
//! execution backend leans on.
//!
//! - any number of threads may submit concurrently (the per-worker
//!   inboxes serialize external pushes; the Chase–Lev deques stay
//!   owner-only),
//! - jobs may submit follow-up jobs from inside the pool (nested
//!   `execute` via [`Submitter`]), and `wait_all` drains whole chains,
//! - `wait_all` with zero jobs returns immediately,
//! - under a seeded randomized schedule no job is lost or run twice,
//! - the job slot table is recycled, not append-only (regression for the
//!   one-slot-per-job leak).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use arcas::sched::{current_worker, HostExecutor, Submitter};
use arcas::topology::Topology;
use arcas::util::Rng;

fn pool(workers: usize) -> HostExecutor {
    HostExecutor::new(workers, &Topology::milan_1s(), false)
}

#[test]
fn zero_job_wait_all_returns_immediately() {
    let p = pool(4);
    p.wait_all();
    p.wait_all(); // and is idempotent
    p.execute(|| {});
    p.wait_all();
    p.wait_all();
}

#[test]
fn concurrent_submitters_from_many_threads() {
    const THREADS: usize = 8;
    const JOBS: u64 = 500;
    let p = pool(4);
    let counter = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let sub: Submitter = p.submitter();
            let counter = counter.clone();
            std::thread::spawn(move || {
                for j in 0..JOBS {
                    let c = counter.clone();
                    // Mix round-robin and targeted submissions.
                    if j % 2 == 0 {
                        sub.execute(move || {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                    } else {
                        sub.execute_on(t + j as usize, move || {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    p.wait_all();
    assert_eq!(counter.load(Ordering::Relaxed), THREADS as u64 * JOBS);
}

#[test]
fn nested_execute_from_inside_jobs() {
    // Each job spawns two children down to depth 6: a full binary tree,
    // 2^7 - 1 = 127 executions from one root submission. wait_all must
    // see the whole chain, not just the root.
    let p = pool(4);
    let counter = Arc::new(AtomicU64::new(0));

    fn spawn_tree(sub: Submitter, counter: Arc<AtomicU64>, depth: u32) {
        counter.fetch_add(1, Ordering::Relaxed);
        if depth == 0 {
            return;
        }
        for _ in 0..2 {
            let sub2 = sub.clone();
            let c = counter.clone();
            sub.execute(move || spawn_tree(sub2, c, depth - 1));
        }
    }

    let sub = p.submitter();
    let c = counter.clone();
    let sub2 = sub.clone();
    sub.execute(move || spawn_tree(sub2, c, 6));
    p.wait_all();
    assert_eq!(counter.load(Ordering::Relaxed), (1 << 7) - 1);
}

#[test]
fn randomized_schedule_loses_nothing_and_runs_nothing_twice() {
    // Seeded random mix of round-robin vs targeted submissions, bursty
    // round sizes, random tiny busy-work, random wait_all points. Every
    // job bumps its own cell: afterwards each must be exactly 1.
    let mut rng = Rng::new(0xA5CA5);
    let p = pool(6);
    const TOTAL: usize = 4000;
    let cells: Arc<Vec<AtomicU64>> = Arc::new((0..TOTAL).map(|_| AtomicU64::new(0)).collect());
    let mut submitted = 0usize;
    while submitted < TOTAL {
        let burst = (1 + rng.gen_range(64) as usize).min(TOTAL - submitted);
        for _ in 0..burst {
            let id = submitted;
            submitted += 1;
            let cells = cells.clone();
            let spin = rng.gen_range(200);
            let job = move || {
                // Tiny random busy-work so jobs overlap with submission.
                for _ in 0..spin {
                    std::hint::spin_loop();
                }
                cells[id].fetch_add(1, Ordering::Relaxed);
            };
            if rng.gen_range(2) == 0 {
                p.execute(job);
            } else {
                p.execute_on(rng.gen_range(16) as usize, job);
            }
        }
        if rng.gen_range(4) == 0 {
            p.wait_all();
        }
    }
    p.wait_all();
    for (id, c) in cells.iter().enumerate() {
        assert_eq!(
            c.load(Ordering::Relaxed),
            1,
            "job {id} ran {} times (must be exactly once)",
            c.load(Ordering::Relaxed)
        );
    }
}

#[test]
fn slot_table_stays_bounded_across_rounds() {
    // Regression: `Shared.jobs` used to be append-only, leaking one slot
    // per job ever submitted. 100 reuse_after_wait-style rounds of 32
    // jobs must not grow the table past one round's in-flight peak.
    let p = pool(2);
    let counter = Arc::new(AtomicU64::new(0));
    for _ in 0..100 {
        for _ in 0..32 {
            let c = counter.clone();
            p.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        p.wait_all();
    }
    assert_eq!(counter.load(Ordering::Relaxed), 3200);
    assert!(
        p.slot_capacity() <= 32,
        "slot table leaked: {} slots alive after 3200 jobs in rounds of 32",
        p.slot_capacity()
    );
}

#[test]
fn jobs_always_observe_a_worker_identity() {
    // current_worker() is how the host backend charges machine time to
    // the core actually running a step: Some(w) on-pool, None off-pool.
    let p = pool(3);
    let bad = Arc::new(AtomicUsize::new(0));
    for _ in 0..100 {
        let bad = bad.clone();
        p.execute(move || match current_worker() {
            Some(w) if w < 3 => {}
            _ => {
                bad.fetch_add(1, Ordering::Relaxed);
            }
        });
    }
    p.wait_all();
    assert_eq!(bad.load(Ordering::Relaxed), 0);
    assert_eq!(current_worker(), None);
}

#[test]
fn steals_rebalance_targeted_floods() {
    // Flood one worker's inbox while the others are idle: thieves must
    // take from the flooded queue (steal counter moves) and everything
    // still runs exactly once.
    let p = pool(8);
    let counter = Arc::new(AtomicU64::new(0));
    for _ in 0..256 {
        let c = counter.clone();
        p.execute_on(0, move || {
            let mut s = 1u64;
            for k in 0..20_000u64 {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            std::hint::black_box(s);
            c.fetch_add(1, Ordering::Relaxed);
        });
    }
    p.wait_all();
    assert_eq!(counter.load(Ordering::Relaxed), 256);
    assert!(
        p.steal_count() > 0,
        "8 idle workers never stole from a flooded victim"
    );
}
