//! OLAP end-to-end: generate a TPC-H-shaped database, run analytical
//! queries on the morsel-parallel engine with and without the ARCAS
//! adaptive controller, verify results against the serial oracle.
//!
//! ```bash
//! cargo run --release --example olap_engine [sf] [cores]
//! ```

use std::sync::Arc;

use arcas::policy::{ArcasPolicy, RingPolicy};
use arcas::topology::Topology;
use arcas::util::table::Table;
use arcas::workloads::olap::{all_queries, run_query, run_query_serial, Db};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sf: f64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(0.02);
    let cores: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);

    let topo = Topology::milan_2s();
    let db = Arc::new(Db::generate(sf, 42));
    println!(
        "database: sf={sf}, lineitem {} rows, total {}",
        db.rows(arcas::workloads::olap::Table::Lineitem),
        arcas::util::fmt_bytes(db.total_bytes())
    );

    let mut t = Table::new(
        "analytical queries: default vs +ARCAS",
        &["query", "rows", "default ms", "+ARCAS ms", "speedup", "verified"],
    );
    // A representative subset: scan-heavy, join-heavy, group-by-heavy.
    for id in [1usize, 3, 5, 6, 9, 12, 18, 21] {
        let q = &all_queries()[id - 1];
        let (rows_ref, sum_ref) = run_query_serial(&db, q);
        let base = run_query(&topo, Box::new(RingPolicy::new()), cores, db.clone(), q);
        let arc = run_query(
            &topo,
            Box::new(ArcasPolicy::new(&topo).with_timer(100_000)),
            cores,
            db.clone(),
            q,
        );
        let verified = base.rows_out == rows_ref
            && arc.rows_out == rows_ref
            && (arc.agg_sum - sum_ref).abs() <= sum_ref.abs() * 1e-9 + 1e-6;
        t.row(vec![
            format!("Q{}", q.id),
            rows_ref.to_string(),
            format!("{:.2}", base.report.makespan_ns as f64 / 1e6),
            format!("{:.2}", arc.report.makespan_ns as f64 / 1e6),
            format!(
                "{:.2}x",
                base.report.makespan_ns as f64 / arc.report.makespan_ns as f64
            ),
            if verified { "ok".into() } else { "MISMATCH".into() },
        ]);
    }
    println!("{}", t.render());
}
