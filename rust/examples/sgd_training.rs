//! **End-to-end driver**: trains a logistic-regression model through the
//! full three-layer stack —
//!
//!   L1 Pallas kernels (tiled matvec) → L2 JAX graph → AOT HLO text →
//!   PJRT CPU executable → L3 ARCAS coordinator (coroutines, chiplet-aware
//!   scheduling on the simulated Milan) —
//!
//! for a few hundred SGD steps on synthetic data, logging the loss curve
//! and throughput, and cross-checking the PJRT numerics against the pure
//! rust oracle. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example sgd_training
//! ```

use std::sync::Arc;

use arcas::policy::ArcasPolicy;
use arcas::runtime::{PjrtGrad, PjrtRuntime};
use arcas::topology::Topology;
use arcas::workloads::sgd::{
    generate_data, run_sgd, DwStrategy, GradEngine, RustGrad, SgdConfig, SgdMode,
};

fn main() {
    let topo = Topology::milan_2s();
    // ~512 steps: 4096 samples / 128 minibatch = 32 batches/task-group
    // epoch x 16 epochs = 512 gradient steps through PJRT. The features
    // are variance-normalized (|x| ~ 1/sqrt(F)), so the step size is
    // correspondingly large.
    let cfg = SgdConfig {
        n_samples: 4096,
        n_features: 1024,
        minibatch: 128,
        epochs: 24,
        lr: 30.0,
        seed: 7,
    };
    println!(
        "dataset: {} x {} ({}), minibatch {}, {} epochs",
        cfg.n_samples,
        cfg.n_features,
        arcas::util::fmt_bytes(cfg.data_bytes()),
        cfg.minibatch,
        cfg.epochs
    );
    let data = generate_data(&cfg);

    // Layer 2/1 via PJRT (falls back to the rust oracle with a warning).
    let dir = PjrtRuntime::default_dir();
    let engine: Arc<dyn GradEngine> = match PjrtRuntime::load(&dir)
        .ok()
        .and_then(|rt| PjrtGrad::new(rt, cfg.minibatch, cfg.n_features).ok())
    {
        Some(g) => {
            println!("gradient engine: PJRT (AOT JAX/Pallas artifact from {dir})");
            Arc::new(g)
        }
        None => {
            eprintln!("WARNING: artifacts not found in {dir}; using rust fallback.");
            eprintln!("         run `make artifacts` for the full three-layer path.");
            Arc::new(RustGrad)
        }
    };

    // Cross-check one minibatch: PJRT vs rust oracle.
    if engine.name() == "pjrt" {
        let nf = cfg.n_features;
        let x = &data.x[..cfg.minibatch * nf];
        let y = &data.y[..cfg.minibatch];
        let w = vec![0.01f32; nf];
        let (lp, gp) = engine.loss_grad(x, y, &w, nf);
        let (lr_, gr) = RustGrad.loss_grad(x, y, &w, nf);
        let gdiff = gp
            .iter()
            .zip(&gr)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!(
            "numerics check: loss pjrt {lp:.6} vs rust {lr_:.6} | max grad diff {gdiff:.2e}"
        );
        assert!((lp - lr_).abs() < 1e-4, "loss mismatch");
        assert!(gdiff < 1e-3, "gradient mismatch");
    }

    // Layer 3: train under the ARCAS adaptive scheduler. 8 workers x 4
    // sequential minibatch steps per epoch x 24 epochs ≈ 770 gradient
    // steps through PJRT, with per-epoch replica averaging.
    let cores = 8;
    let t0 = std::time::Instant::now();
    let run = run_sgd(
        &topo,
        Box::new(ArcasPolicy::new(&topo).with_timer(100_000)),
        cores,
        &cfg,
        &data,
        DwStrategy::PerNode,
        SgdMode::Grad,
        engine,
    );
    let wall = t0.elapsed();

    println!("\nloss curve (per-epoch aggregated minibatch loss):");
    let first = run.loss_trace[0];
    for (e, l) in run.loss_trace.iter().enumerate() {
        let bars = ((l / first) * 50.0) as usize;
        println!("  epoch {e:>2}: {l:>10.4} |{}|", "#".repeat(bars.min(60)));
    }
    println!("\nfinal loss        {:.4} (from {:.4})", run.final_loss, first);
    println!("virtual makespan  {}", arcas::util::fmt_ns(run.report.makespan_ns));
    println!("throughput        {:.1} GB/s (virtual, paper metric)", run.gbps());
    println!("wall time         {:.2} s", wall.as_secs_f64());
    println!("dispatches        {}", run.report.dispatches);
    println!("final spread rate {}", run.report.spread_rate);

    assert!(
        run.final_loss < first * 0.5,
        "training must reduce the loss (got {} from {})",
        run.final_loss,
        first
    );
    println!("\nOK: end-to-end three-layer training converged.");
}
