//! Graph analytics end-to-end: Kronecker graph → four algorithms under
//! ARCAS vs RING, results verified against serial references.
//!
//! ```bash
//! cargo run --release --example graph_analytics [scale] [cores]
//! ```

use std::sync::Arc;

use arcas::policy::{ArcasPolicy, RingPolicy};
use arcas::topology::Topology;
use arcas::util::table::Table;
use arcas::workloads::graph::{algos, kronecker::kronecker, run_bfs, run_cc, run_pagerank, run_sssp};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale: u32 = args.first().and_then(|s| s.parse().ok()).unwrap_or(14);
    let cores: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);

    let topo = Topology::milan_2s();
    let g = Arc::new(kronecker(scale, 16, 42));
    println!(
        "graph: 2^{scale} vertices, {} edges ({}); {} cores on {}",
        g.num_edges(),
        arcas::util::fmt_bytes(g.bytes()),
        cores,
        topo.name
    );

    let src = g.max_degree_vertex();
    let arcas_p = || Box::new(ArcasPolicy::new(&topo).with_timer(100_000));
    let ring_p = || Box::new(RingPolicy::new());

    let mut t = Table::new(
        "graph analytics: ARCAS vs RING",
        &["algorithm", "ARCAS ms", "RING ms", "speedup", "verified"],
    );

    // BFS.
    let (a, dist_a) = run_bfs(&topo, arcas_p(), cores, g.clone(), src);
    let (r, _) = run_bfs(&topo, ring_p(), cores, g.clone(), src);
    let ok = dist_a == algos::bfs_ref(&g, src);
    t.row(row("BFS", &a.report, &r.report, ok));

    // PageRank.
    let (a, pr_a) = run_pagerank(&topo, arcas_p(), cores, g.clone(), 10);
    let (r, _) = run_pagerank(&topo, ring_p(), cores, g.clone(), 10);
    let pr_ref = algos::pagerank_ref(&g, 10);
    let ok = pr_a
        .iter()
        .zip(&pr_ref)
        .all(|(x, y)| (x - y).abs() < 1e-9);
    t.row(row("PageRank", &a.report, &r.report, ok));

    // Connected components.
    let (a, cc_a) = run_cc(&topo, arcas_p(), cores, g.clone());
    let (r, _) = run_cc(&topo, ring_p(), cores, g.clone());
    let ok = algos::component_count(&cc_a) == algos::component_count(&algos::cc_ref(&g));
    t.row(row("CC", &a.report, &r.report, ok));

    // SSSP.
    let (a, d_a) = run_sssp(&topo, arcas_p(), cores, g.clone(), src);
    let (r, _) = run_sssp(&topo, ring_p(), cores, g.clone(), src);
    let ok = d_a == algos::sssp_ref(&g, src);
    t.row(row("SSSP", &a.report, &r.report, ok));

    println!("{}", t.render());
    println!("counters (last run): ARCAS far accesses {:.0}, RING far accesses {:.0}",
        a.report.counts.far, r.report.counts.far);
}

fn row(
    name: &str,
    a: &arcas::sched::RunReport,
    r: &arcas::sched::RunReport,
    verified: bool,
) -> Vec<String> {
    vec![
        name.to_string(),
        format!("{:.2}", a.makespan_ns as f64 / 1e6),
        format!("{:.2}", r.makespan_ns as f64 / 1e6),
        format!("{:.2}x", r.makespan_ns as f64 / a.makespan_ns as f64),
        if verified { "ok".into() } else { "MISMATCH".into() },
    ]
}
