//! Quickstart: the ARCAS API in ~40 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use arcas::api::{Arcas, ArcasConfig};
use arcas::mem::Placement;
use arcas::topology::Topology;

fn main() {
    // ARCAS_Init() — dual-socket Milan model, adaptive policy.
    let mut rt = Arcas::init_with(ArcasConfig {
        topology: Topology::milan_2s(),
        timer_ns: 100_000,
        ..Default::default()
    });
    println!("machine: {}", rt.topology().summary());

    // Allocate a shared 64 MiB region, interleaved across NUMA nodes.
    let data = rt.alloc("dataset", 64 << 20, Placement::Interleave);

    // all_do(): run one task per rank; each streams its slice and does
    // some math. Yield points are where ARCAS profiles and migrates.
    let report = rt.all_do_chunked(32, 16, move |ctx, rank, _chunk| {
        ctx.seq_read(data, 2 << 20);
        ctx.compute_flops(1_000_000);
        let _ = rank;
    });

    println!("policy            {}", report.policy);
    println!("makespan          {}", arcas::util::fmt_ns(report.makespan_ns));
    println!("dispatches        {}", report.dispatches);
    println!("steals            {}", report.steals);
    println!("final spread rate {}", report.spread_rate);
    let c = &report.counts;
    println!(
        "accesses          local {:.0} | near {:.0} | far {:.0} | dram {:.0}",
        c.local, c.near, c.far, c.dram
    );

    // Synchronous RPC to a specific core (the `call()` API).
    let answer = rt.call(0, 9, |ctx| {
        ctx.compute_ns(50);
        42
    });
    println!("call(core 9)      -> {answer}");

    // The same runtime also runs on real OS threads (host executor).
    let pool = arcas::sched::HostExecutor::new(4, rt.topology(), false);
    let hits = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
    for _ in 0..64 {
        let hits = hits.clone();
        pool.execute(move || {
            hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
    }
    pool.wait_all();
    println!(
        "host executor     ran {} jobs on {} workers ({} steals)",
        hits.load(std::sync::atomic::Ordering::Relaxed),
        pool.workers(),
        pool.steal_count()
    );

    rt.finalize();
}
