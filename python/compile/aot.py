"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for rust.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit-instruction-id
protos, while the text parser reassigns ids (see aot_recipe /
/opt/xla-example). Each artifact is listed in ``artifacts/manifest.txt``
(INI, parsed by rust's `util::config`) with its entry point and shapes.

Usage: python -m compile.aot [--out-dir ../artifacts]
Re-running is a no-op if inputs are unchanged (Makefile dependency).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), jnp.float32)


def _shape_str(dims):
    return "x".join(str(d) for d in dims) if dims else "scalar"


# Artifact registry: name -> (fn, input specs, output dims-for-manifest).
def registry():
    entries = {}

    def add(name, fn, in_specs, out_dims):
        entries[name] = (fn, in_specs, out_dims)

    for b, f in [(128, 1024), (64, 64), (256, 2048)]:
        add(
            f"logreg_loss_grad_b{b}_f{f}",
            model.logreg_loss_grad,
            [_spec(b, f), _spec(b), _spec(f)],
            [(), (f,)],
        )
        add(
            f"sgd_step_b{b}_f{f}",
            model.sgd_step,
            [_spec(b, f), _spec(b), _spec(f), _spec()],
            [(), (f,)],
        )
    for n, k, d in [(512, 32, 64), (256, 16, 16)]:
        add(
            f"pdist_n{n}_k{k}_d{d}",
            model.pairwise_dist,
            [_spec(n, d), _spec(k, d)],
            [(n, k)],
        )
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="lower a single entry")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, (fn, in_specs, out_dims) in sorted(registry().items()):
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest_lines.append(f"[{name}]")
        manifest_lines.append(f"file = {fname}")
        manifest_lines.append(
            "inputs = " + ";".join(_shape_str(s.shape) for s in in_specs)
        )
        manifest_lines.append(
            "outputs = " + ";".join(_shape_str(d) for d in out_dims)
        )
        manifest_lines.append("")
        print(f"lowered {name}: {len(text)} chars")

    if not args.only:
        with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest_lines))
        print(f"wrote manifest with {len(registry())} entries")


if __name__ == "__main__":
    main()
