"""L1 Pallas kernels for the logistic-regression hot spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles the
working set to per-chiplet 32 MB L3 slices and co-locates compute
(Algorithm 2). On TPU the same insight becomes VMEM-blocked matmuls: the
sample matrix is split into (BM × BK) blocks that fit the VMEM budget, the
grid walks HBM block-by-block (the BlockSpec index_map is the rank→tile
map), and partial results accumulate in the output block — compute next to
the tile, exactly the chiplet story.

Kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode lowers to plain HLO (see
/opt/xla-example/README.md). Block shapes stay multiples of (8, 128) so
the same kernels compile for a real TPU MXU unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget heuristic: a (BM, BK) f32 block + vector operands should
# stay well under ~16 MiB of VMEM. 256×512×4 B = 512 KiB per block.
DEFAULT_BM = 256
DEFAULT_BK = 512


def _pick_block(dim, pref, floor):
    """Largest divisor of `dim` that is <= pref, >= floor if possible."""
    if dim <= pref:
        return dim
    for cand in range(pref, floor - 1, -1):
        if dim % cand == 0:
            return cand
    return dim  # fall back to a single block


def _matvec_kernel(x_ref, w_ref, o_ref):
    """One grid step: o[i-block] += X[i-block, k-block] @ w[k-block]."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BM, BK) @ (BK,) accumulated in f32.
    o_ref[...] += x_ref[...] @ w_ref[...]


def matvec(x, w, bm=None, bk=None, interpret=True):
    """z = X @ w with X: (B, F) f32, w: (F,) f32, VMEM-tiled."""
    b, f = x.shape
    bm = bm or _pick_block(b, DEFAULT_BM, 8)
    bk = bk or _pick_block(f, DEFAULT_BK, 128)
    grid = (pl.cdiv(b, bm), pl.cdiv(f, bk))
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(x, w)


def _matvec_t_kernel(x_ref, e_ref, o_ref):
    """One grid step: g[k-block] += X[i-block, k-block]^T @ e[i-block]."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...].T @ e_ref[...]


def matvec_t(x, e, bm=None, bk=None, interpret=True):
    """g = X^T @ e with X: (B, F), e: (B,), VMEM-tiled.

    The accumulation dimension (samples) is the *inner* grid axis so the
    output block stays resident while partials accumulate — the
    double-buffering-friendly schedule.
    """
    b, f = x.shape
    bm = bm or _pick_block(b, DEFAULT_BM, 8)
    bk = bk or _pick_block(f, DEFAULT_BK, 128)
    grid = (pl.cdiv(f, bk), pl.cdiv(b, bm))
    return pl.pallas_call(
        _matvec_t_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda k, i: (i, k)),
            pl.BlockSpec((bm,), lambda k, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bk,), lambda k, i: (k,)),
        out_shape=jax.ShapeDtypeStruct((f,), jnp.float32),
        interpret=interpret,
    )(x, e)


@functools.partial(jax.jit, static_argnames=("interpret",))
def logreg_loss_grad(x, y, w, interpret=True):
    """Minibatch logistic loss + gradient, hot paths in Pallas.

    Semantics match ``ref.logreg_loss_grad_ref`` and the rust RustGrad
    engine bit-for-bit-ish (f32 accumulation order differs).
    """
    b = x.shape[0]
    z = matvec(x, w, interpret=interpret)
    p = 1.0 / (1.0 + jnp.exp(-z))
    pc = jnp.clip(p, 1e-7, 1.0 - 1e-7)
    loss = -jnp.mean(y * jnp.log(pc) + (1.0 - y) * jnp.log(1.0 - pc))
    err = p - y
    grad = matvec_t(x, err, interpret=interpret) / b
    return loss, grad


@functools.partial(jax.jit, static_argnames=("interpret",))
def sgd_step(x, y, w, lr, interpret=True):
    """One fused SGD step: (loss, w_new)."""
    loss, grad = logreg_loss_grad(x, y, w, interpret=interpret)
    return loss, w - lr * grad
