"""L1 Pallas kernel: pairwise squared distances (StreamCluster hot spot).

D(n, k) = |P_n|^2 + |C_k|^2 - 2 P_n · C_k — expressed as a blocked matmul
so the inner product runs on the MXU. The point matrix is tiled along N
(the streaming axis — one batch slice per grid step, the VMEM analog of
ARCAS streaming a batch slice through a chiplet's L3); the center matrix
is small and stays resident across grid steps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 256


def _pdist_kernel(p_ref, c_ref, o_ref):
    p = p_ref[...]  # (BN, D)
    c = c_ref[...]  # (K, D)
    pn = jnp.sum(p * p, axis=1, keepdims=True)  # (BN, 1)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, K)
    o_ref[...] = pn + cn - 2.0 * (p @ c.T)


def _pick_block(dim, pref, floor):
    if dim <= pref:
        return dim
    for cand in range(pref, floor - 1, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("interpret",))
def pdist(p, c, interpret=True):
    """Squared distances, P: (N, D), C: (K, D) -> (N, K)."""
    n, d = p.shape
    k = c.shape[0]
    bn = _pick_block(n, DEFAULT_BN, 8)
    return pl.pallas_call(
        _pdist_kernel,
        grid=(pl.cdiv(n, bn),),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(p, c)


@functools.partial(jax.jit, static_argnames=("interpret",))
def assign_points(p, c, interpret=True):
    """StreamCluster assignment step: nearest-center index + cost.

    Returns (assignment (N,) int32, min squared distance (N,) f32).
    """
    d = pdist(p, c, interpret=interpret)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1)
