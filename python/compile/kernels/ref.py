"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite (and, transitively, the rust
`RustGrad` engine) is validated against. Everything is plain jax.numpy —
no pallas, no custom calls.
"""

import jax.numpy as jnp


def matvec_ref(x, w):
    """z = X @ w for X: (B, F), w: (F,)."""
    return x @ w


def matvec_t_ref(x, e):
    """g = X^T @ e for X: (B, F), e: (B,)."""
    return x.T @ e


def logreg_loss_grad_ref(x, y, w):
    """Minibatch logistic loss + gradient.

    Matches rust `RustGrad::loss_grad`: mean BCE loss, gradient of the
    mean loss w.r.t. w.
    """
    z = x @ w
    p = jnp.clip(1.0 / (1.0 + jnp.exp(-z)), 1e-7, 1.0 - 1e-7)
    loss = -jnp.mean(y * jnp.log(p) + (1.0 - y) * jnp.log(1.0 - p))
    err = (1.0 / (1.0 + jnp.exp(-z))) - y
    grad = x.T @ err / x.shape[0]
    return loss, grad


def sgd_step_ref(x, y, w, lr):
    """One SGD step: returns (loss, w - lr * grad)."""
    loss, grad = logreg_loss_grad_ref(x, y, w)
    return loss, w - lr * grad


def pdist_ref(p, c):
    """Squared euclidean distances, P: (N, D), C: (K, D) -> (N, K)."""
    pn = jnp.sum(p * p, axis=1, keepdims=True)  # (N, 1)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, K)
    return pn + cn - 2.0 * (p @ c.T)
