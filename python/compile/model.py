"""L2: the JAX compute graphs ARCAS executes through PJRT.

Each function here is a complete jax program calling the L1 Pallas
kernels; `aot.py` lowers them once to HLO text for the rust runtime.
Python never runs on the request path — these definitions exist only at
build time.
"""

import jax.numpy as jnp

from compile.kernels import logreg, pdist


def sgd_step(x, y, w, lr):
    """One SGD step over a fixed-size minibatch.

    Inputs:  x (B, F) f32, y (B,) f32, w (F,) f32, lr () f32.
    Outputs: (loss () f32, w_new (F,) f32).
    """
    return logreg.sgd_step(x, y, w, lr)


def logreg_loss_grad(x, y, w):
    """Loss + gradient without the update (Fig. 10's two measurements).

    Outputs: (loss () f32, grad (F,) f32).
    """
    return logreg.logreg_loss_grad(x, y, w)


def logreg_loss(x, y, w):
    """Forward-only loss (Fig. 10a)."""
    loss, _ = logreg.logreg_loss_grad(x, y, w)
    return (loss,)


def pairwise_assign(p, c):
    """StreamCluster assignment: (assignment (N,) i32, cost (N,) f32)."""
    return pdist.assign_points(p, c)


def pairwise_dist(p, c):
    """Raw squared-distance matrix (N, K)."""
    return (pdist.pdist(p, c),)
