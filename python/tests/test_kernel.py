"""Kernel-vs-reference correctness: the CORE L1 signal.

Pallas kernels (interpret=True) must match the pure-jnp oracles across
shapes and values — hypothesis sweeps shapes, fixed tests pin the AOT
shapes used by the rust runtime.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logreg, pdist, ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# --------------------------------------------------------------------
# matvec kernels
# --------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([8, 16, 64, 128, 256]),
    f=st.sampled_from([128, 256, 512, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_matches_ref(b, f, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, b, f), rand(rng, f)
    got = logreg.matvec(x, w)
    want = ref.matvec_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([8, 16, 64, 128, 256]),
    f=st.sampled_from([128, 256, 512, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_t_matches_ref(b, f, seed):
    rng = np.random.default_rng(seed)
    x, e = rand(rng, b, f), rand(rng, b)
    got = logreg.matvec_t(x, e)
    want = ref.matvec_t_ref(x, e)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matvec_non_divisible_blocks():
    # Odd sizes exercise the block-picking fallback paths.
    rng = np.random.default_rng(0)
    for b, f in [(7, 130), (33, 257), (1, 128), (300, 1000)]:
        x, w = rand(rng, b, f), rand(rng, f)
        np.testing.assert_allclose(
            logreg.matvec(x, w), ref.matvec_ref(x, w), rtol=3e-4, atol=3e-4
        )


# --------------------------------------------------------------------
# logistic regression loss/grad/step
# --------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([8, 32, 64, 128]),
    f=st.sampled_from([16, 64, 256, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_loss_grad_matches_ref(b, f, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, f) / np.sqrt(f)
    y = jnp.asarray((rng.random(b) > 0.5).astype(np.float32))
    w = rand(rng, f)
    loss, grad = logreg.logreg_loss_grad(x, y, w)
    loss_r, grad_r = ref.logreg_loss_grad_ref(x, y, w)
    np.testing.assert_allclose(loss, loss_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(grad, grad_r, rtol=2e-4, atol=1e-5)


def test_gradient_against_jax_autodiff():
    # The hand-derived gradient must equal jax.grad of the loss.
    rng = np.random.default_rng(7)
    b, f = 32, 64
    x = rand(rng, b, f) / 8.0
    y = jnp.asarray((rng.random(b) > 0.5).astype(np.float32))
    w = rand(rng, f)

    def pure_loss(w):
        return ref.logreg_loss_grad_ref(x, y, w)[0]

    auto = jax.grad(pure_loss)(w)
    _, ours = logreg.logreg_loss_grad(x, y, w)
    np.testing.assert_allclose(ours, auto, rtol=5e-4, atol=1e-5)


def test_sgd_step_reduces_loss():
    rng = np.random.default_rng(3)
    b, f = 128, 256
    w_true = rand(rng, f)
    x = rand(rng, b, f) / np.sqrt(f)
    y = (ref.matvec_ref(x, w_true) > 0).astype(jnp.float32)
    w = jnp.zeros(f)
    loss0, w = logreg.sgd_step(x, y, w, 5.0)
    loss1, w = logreg.sgd_step(x, y, w, 5.0)
    loss2, _ = logreg.sgd_step(x, y, w, 5.0)
    assert loss1 < loss0
    assert loss2 < loss1


def test_aot_shapes_exactly():
    # Pin the shapes aot.py lowers for the rust runtime.
    rng = np.random.default_rng(11)
    for b, f in [(128, 1024), (64, 64), (256, 2048)]:
        x = rand(rng, b, f) / np.sqrt(f)
        y = jnp.asarray((rng.random(b) > 0.5).astype(np.float32))
        w = rand(rng, f)
        loss, grad = logreg.logreg_loss_grad(x, y, w)
        loss_r, grad_r = ref.logreg_loss_grad_ref(x, y, w)
        np.testing.assert_allclose(loss, loss_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(grad, grad_r, rtol=3e-4, atol=1e-5)


# --------------------------------------------------------------------
# pairwise distance kernel
# --------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([8, 64, 256, 512]),
    k=st.sampled_from([1, 4, 16, 32]),
    d=st.sampled_from([2, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pdist_matches_ref(n, k, d, seed):
    rng = np.random.default_rng(seed)
    p, c = rand(rng, n, d), rand(rng, k, d)
    got = pdist.pdist(p, c)
    want = ref.pdist_ref(p, c)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_pdist_is_nonnegative_and_zero_diagonal():
    rng = np.random.default_rng(1)
    p = rand(rng, 16, 8)
    d = np.asarray(pdist.pdist(p, p))
    assert (d > -1e-4).all()
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-4)


def test_assign_points_picks_nearest():
    p = jnp.asarray([[0.0, 0.0], [10.0, 10.0], [0.1, 0.0]], dtype=jnp.float32)
    c = jnp.asarray([[0.0, 0.0], [10.0, 10.0]], dtype=jnp.float32)
    a, cost = pdist.assign_points(p, c)
    assert list(np.asarray(a)) == [0, 1, 0]
    np.testing.assert_allclose(cost[0], 0.0, atol=1e-6)


# --------------------------------------------------------------------
# AOT lowering produces loadable HLO text
# --------------------------------------------------------------------

def test_lowering_emits_hlo_text(tmp_path):
    import jax as _jax
    from compile import aot, model

    lowered = _jax.jit(model.pairwise_dist).lower(
        _jax.ShapeDtypeStruct((256, 16), jnp.float32),
        _jax.ShapeDtypeStruct((16, 16), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[256,16]" in text


def test_registry_covers_required_entries():
    from compile import aot

    names = set(aot.registry().keys())
    assert "logreg_loss_grad_b128_f1024" in names
    assert "sgd_step_b128_f1024" in names
    assert "pdist_n512_k32_d64" in names
