# ARCAS reproduction — tooling entry points.
#
#   make verify       tier-1 gate: release build + full test suite
#   make fmt          rustfmt check (no writes)
#   make clippy       clippy with warnings denied
#   make ci           everything CI runs, in order (all three workflow jobs)
#   make host-suites  the release-mode host-backend suites CI's host job runs
#   make host-scaling host-backend scaling smoke (BENCH_host_scaling.json)
#   make sched-overhead  scheduler-overhead smoke: batched stepping must
#                     beat --batch-steps 1 by 2x (BENCH_sched_overhead.json)
#   make mem-follow   memory-follows-tasks smoke: region moves must beat
#                     the task-move-only baseline (BENCH_mem_follow.json)
#   make fig-cluster  cluster scale-out smoke: 4 shards must beat 1
#                     machine on rps-at-p99 (BENCH_cluster_scaling.json)
#   make bench-regression  serving bench + baseline gates (CI's bench job)
#   make artifacts    AOT-lower the JAX/Pallas kernels to HLO text (needs
#                     python + jax; the rust build runs fine without them)
#   make bench-smoke  quick pass over two figure benches

.PHONY: verify build test fmt clippy ci artifacts bench-smoke host-suites host-scaling sched-overhead adaptive-payoff mem-follow fig-cluster bench-regression

verify: build test

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Mirror of both CI tiers: ci.yml's fast tier (fmt+clippy+verify, the
# release-mode suites, the --quick bench smokes + gates) plus the extra
# full-size smokes nightly.yml adds — so a local `make ci` reproduces
# everything the workflows enforce (except the TSan pass, which needs
# a nightly toolchain: see nightly.yml's tsan job).
ci: fmt clippy verify host-suites bench-regression

# Release-mode host-backend suites with bounded parallelism (what CI's
# host-backend job runs; debug-mode coverage already comes via `test`).
host-suites:
	cargo test --release --test backend_conformance --test host_stress --test cli_args --test shard_equivalence -- --test-threads 2

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts

bench-smoke:
	cargo bench --bench fig13_oltp -- --quick --scale 0.002
	cargo bench --bench fig05_local_vs_dist -- --quick

# Host-backend scaling smoke: multi-worker wall time must beat 1-worker
# on a memory-bound scenario (sharded accounting = no whole-machine
# lock). Emits BENCH_host_scaling.json.
host-scaling:
	cargo bench --bench micro_runtime -- --scaling-only --assert-scaling --scaling-reps 5 --workers 1,8

# Scheduler-overhead smoke: batched host stepping (run-until-yield,
# --batch-steps 16) must beat the step-per-job pipeline (--batch-steps 1)
# by >= 2x at zero work on 8 workers. Emits BENCH_sched_overhead.json.
sched-overhead:
	cargo bench --bench micro_runtime -- --overhead-only --assert-overhead

# Adaptive-migration smoke: on the phase-shift scenario (message-bound
# then bandwidth-bound) the adaptive policy must migrate at the shift —
# real-elapsed host timer — and beat every static placement's modeled
# makespan. Emits BENCH_adaptive.json.
adaptive-payoff:
	cargo bench --bench micro_runtime -- --adaptive-only --assert-adaptive --quick

# Memory-follows-tasks smoke: a stranded Bind region whose accessors all
# live on another NUMA node — the adaptive policy with region moves on
# must re-home it (region_moves > 0) and beat the --no-region-moves
# task-move-only baseline's makespan. Emits BENCH_mem_follow.json.
mem-follow:
	cargo bench --bench micro_runtime -- --mem-follow-only --assert-mem-follow --quick

# Cluster scale-out smoke: run the rps-at-p99 rate ladder for 1 and 4
# machines on the drifting-hotspot serve trace and require the 4-shard
# cluster to beat the single machine. Emits BENCH_cluster_scaling.json.
fig-cluster:
	cargo bench --bench fig_cluster -- --quick --assert-scaling

# The CI bench-regression gate, locally: run fig_serving + the scaling,
# overhead, adaptive and cluster smokes, then compare the emitted BENCH_*.json against
# ci/baselines/ (fail on regression, warn on improvement; unpinned
# baselines only report). fig_serving emits the latency file, the
# SLO-section file (per-class p99 + shed rate, gated via the per-entry
# "metric" key) and the throughput file (rps at a fixed p99 budget,
# gated higher-is-better). Cargo runs bench binaries with CWD = the
# package root, so the emitted BENCH_*.json files land under rust/.
# Re-pin all baselines from fresh artifacts: `arcas bench-check --pin`.
bench-regression: build host-scaling sched-overhead adaptive-payoff mem-follow fig-cluster
	cargo bench --bench fig_serving -- --quick
	./target/release/arcas bench-check --kind serving --baseline ci/baselines/BENCH_serving_latency.json --current rust/BENCH_serving_latency.json
	./target/release/arcas bench-check --kind serving --baseline ci/baselines/BENCH_serving_slo.json --current rust/BENCH_serving_slo.json
	./target/release/arcas bench-check --kind serving --baseline ci/baselines/BENCH_serving_throughput.json --current rust/BENCH_serving_throughput.json
	./target/release/arcas bench-check --kind cluster --baseline ci/baselines/BENCH_cluster_scaling.json --current rust/BENCH_cluster_scaling.json
	./target/release/arcas bench-check --kind overhead --baseline ci/baselines/BENCH_sched_overhead.json --current rust/BENCH_sched_overhead.json
	./target/release/arcas bench-check --kind scaling --baseline ci/baselines/BENCH_host_scaling.json --current rust/BENCH_host_scaling.json
	./target/release/arcas bench-check --kind adaptive --baseline ci/baselines/BENCH_adaptive.json --current rust/BENCH_adaptive.json
	./target/release/arcas bench-check --kind mem-follow --baseline ci/baselines/BENCH_mem_follow.json --current rust/BENCH_mem_follow.json
