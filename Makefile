# ARCAS reproduction — tooling entry points.
#
#   make verify     tier-1 gate: release build + full test suite
#   make fmt        rustfmt check (no writes)
#   make clippy     clippy with warnings denied
#   make ci         everything CI runs, in order
#   make artifacts  AOT-lower the JAX/Pallas kernels to HLO text (needs
#                   python + jax; the rust build runs fine without them)
#   make bench-smoke  quick pass over two figure benches

.PHONY: verify build test fmt clippy ci artifacts bench-smoke host-scaling

verify: build test

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --all-targets -- -D warnings

ci: fmt clippy verify

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts

bench-smoke:
	cargo bench --bench fig13_oltp -- --quick --scale 0.002
	cargo bench --bench fig05_local_vs_dist -- --quick

# Host-backend scaling smoke: multi-worker wall time must beat 1-worker
# on a memory-bound scenario (sharded accounting = no whole-machine
# lock). Emits BENCH_host_scaling.json.
host-scaling:
	cargo bench --bench micro_runtime -- --scaling-only --assert-scaling --workers 1,8
